//! Storage-tier integration tests.
//!
//! The load-bearing properties: (1) differential — with
//! `storage_tier.enabled = false` (the default), every other storage
//! knob cranked and `dual_path` set to anything, the engine is
//! bit-identical to the pre-storage oracle at N=1 and the pre-storage
//! cluster at N=4; (2) replay — a storage-on run is deterministic;
//! (3) the acceptance claim — on a pressured grid the per-request
//! dual-path policy strictly beats *both* pure policies (always-reload
//! and always-recompute) on batch latency in at least one cell.
//!
//! (The extent map's internal invariants and the argmin/crossover
//! property of the decision rule are pinned in `engine/storage.rs`
//! unit tests.)

mod common;

use common::{assert_bit_identical, random_jobs, reference_run};
use concur::config::{DualPathMode, EvictionMode, JobConfig, RouterKind, StorageTierConfig};
use concur::core::Micros;
use concur::driver::{run_job, RunResult};
use concur::metrics::Phase;
use concur::repro::run_systems;
use concur::repro::storage::{base_job, POLICIES};

/// Crank every dormant knob: `enabled` stays false, everything else is
/// set to values that would visibly change behavior if they leaked.
fn cranked_dormant() -> StorageTierConfig {
    StorageTierConfig {
        enabled: false,
        capacity_tokens: 1,
        bandwidth_gbps: 0.000_1,
        cpu_tier_tokens: 1,
    }
}

/// PROPERTY (differential, N=1): with the storage tier disabled the
/// engine is bit-identical to the embedded pre-storage oracle, whatever
/// the dormant knobs or the (equally dormant) `dual_path` mode say.
/// Any storage bookkeeping leaking into the two-tier path — a demotion
/// sink, a CPU-cap override, an extent probe on admit — breaks this
/// immediately.
#[test]
fn n1_storage_off_is_bit_identical_to_the_oracle() {
    for (i, base) in random_jobs(6).iter().enumerate() {
        let want = reference_run(base);
        for mode in [
            DualPathMode::AlwaysReload,
            DualPathMode::AlwaysRecompute,
            DualPathMode::DualPath,
        ] {
            let mut job = base.clone();
            job.engine.storage_tier = cranked_dormant();
            job.engine.dual_path = mode;
            let got = run_job(&job).unwrap();
            assert_bit_identical(&got, &want, &format!("job {i} dormant storage {mode:?}"));
            assert_eq!(
                got.breakdown.get(Phase::StorageReload),
                Micros::ZERO,
                "job {i}: no storage-reload time without a storage tier"
            );
        }
    }
}

fn n4_job() -> JobConfig {
    let mut job = common::small_cluster_job(24, 4, RouterKind::CacheAffinity);
    job.engine.eviction = EvictionMode::Offload;
    job
}

/// PROPERTY (differential, N=4): same invisibility through the sharded
/// cluster loop — a dormant storage tier on every replica changes
/// nothing about a 4-replica run.
#[test]
fn n4_storage_off_machinery_is_invisible() {
    let plain = n4_job();
    let want = run_job(&plain).unwrap();
    let mut dormant = plain.clone();
    dormant.engine.storage_tier = cranked_dormant();
    dormant.engine.dual_path = DualPathMode::DualPath;
    let got = run_job(&dormant).unwrap();
    assert_bit_identical(&got, &want, "N=4 dormant storage");
    assert_eq!(got.breakdown.get(Phase::StorageReload), Micros::ZERO);
    assert_eq!(got.counters.storage_demoted_tokens, 0);
    assert_eq!(got.counters.storage_reloaded_tokens, 0);
    assert_eq!(got.counters.storage_recomputed_tokens, 0);
    assert_eq!(got.counters.storage_evicted_tokens, 0);
}

/// PROPERTY (replay): a storage-on run — demotions, reloads and the
/// per-request decision included — replays bit-identically, and the
/// tier genuinely engages (the identity is not vacuous).
#[test]
fn storage_on_runs_replay_bit_identically() {
    let job = base_job(DualPathMode::DualPath, 3.0, 12);
    let a = run_job(&job).unwrap();
    let b = run_job(&job).unwrap();
    assert_bit_identical(&a, &b, "storage-on replay");
    assert!(
        a.counters.storage_demoted_tokens > 0,
        "the replay cell must actually demote to storage"
    );
}

/// ACCEPTANCE (tentpole, scaled down from `concur repro storage`): on a
/// pressured mini-grid — two storage-link bandwidths bracketing the
/// reload/recompute break-even, one fleet size against one TP2 pool
/// with a squeezed CPU tier — the per-request dual-path policy strictly
/// beats BOTH pure policies on batch latency in at least one cell.
/// Within a cell the fleets are identical across policies, so any
/// latency gap is the reload decision's doing.
#[test]
fn dual_path_strictly_beats_both_pure_policies_somewhere() {
    const BANDWIDTHS: [f64; 3] = [0.8, 3.0, 6.0];
    const N_AGENTS: usize = 24;

    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for &gbps in &BANDWIDTHS {
        for &policy in &POLICIES {
            labels.push((gbps, policy));
            jobs.push(base_job(policy, gbps, N_AGENTS));
        }
    }
    let results = run_systems(jobs).unwrap();
    fn cell<'a>(
        labels: &[(f64, DualPathMode)],
        results: &'a [RunResult],
        gbps: f64,
        policy: DualPathMode,
    ) -> &'a RunResult {
        let i = labels
            .iter()
            .position(|&(g, p)| g == gbps && p == policy)
            .expect("complete grid");
        &results[i]
    }

    let mut strict_wins = 0;
    let mut dual_reloaded = 0u64;
    let mut dual_recomputed = 0u64;
    for &gbps in &BANDWIDTHS {
        let rl = cell(&labels, &results, gbps, DualPathMode::AlwaysReload);
        let rc = cell(&labels, &results, gbps, DualPathMode::AlwaysRecompute);
        let dp = cell(&labels, &results, gbps, DualPathMode::DualPath);
        for (name, r) in [("always-reload", rl), ("always-recompute", rc), ("dual-path", dp)] {
            assert_eq!(
                r.agents_finished, N_AGENTS,
                "{gbps} GB/s {name}: every policy must finish the fleet"
            );
            assert!(
                r.counters.storage_demoted_tokens > 0,
                "{gbps} GB/s {name}: the cell must demote to storage — \
                 without demotions there is no decision to compare"
            );
        }
        // The pure policies genuinely take their path.
        assert_eq!(rl.counters.storage_recomputed_tokens, 0, "{gbps}: reload never recomputes");
        assert_eq!(rc.counters.storage_reloaded_tokens, 0, "{gbps}: recompute never reloads");
        dual_reloaded += dp.counters.storage_reloaded_tokens;
        dual_recomputed += dp.counters.storage_recomputed_tokens;
        if dp.total_time < rl.total_time && dp.total_time < rc.total_time {
            strict_wins += 1;
        }
    }
    // Across the bracket the decision rule must actually mix paths —
    // if it collapses to one pure policy everywhere, the strict win
    // below would be luck, not policy.
    assert!(
        dual_reloaded > 0 && dual_recomputed > 0,
        "dual-path never mixed (reloaded {dual_reloaded}, recomputed {dual_recomputed})"
    );
    assert!(
        strict_wins > 0,
        "dual-path beat both pure policies in no cell: {:?}",
        labels
            .iter()
            .zip(&results)
            .map(|(&(g, p), r)| format!("{g}/{}={}", p.name(), r.total_time))
            .collect::<Vec<_>>()
    );
}
