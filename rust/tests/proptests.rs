//! Property-based tests on the crate's core invariants.
//!
//! The vendored crate set has no proptest, so properties are checked with
//! seeded random-case sweeps (hundreds of cases per property, bit-stable
//! across runs).  Each property states the invariant it defends.

use concur::core::{Micros, Rng, Token};
use concur::engine::{EvictPolicy, RadixTree};

/// Random token sequence with a shared low-id prefix pool so sequences
/// overlap in interesting ways.
fn random_seq(rng: &mut Rng, max_len: usize) -> Vec<Token> {
    let len = rng.gen_range(1, max_len as u64 + 1) as usize;
    let share_prefix = rng.chance(0.6);
    let mut seq = Vec::with_capacity(len);
    if share_prefix {
        let plen = rng.gen_range(1, 64).min(len as u64) as usize;
        let family = rng.gen_range(0, 4) as u32;
        seq.extend((0..plen as u32).map(|i| family * 1000 + i));
    }
    while seq.len() < len {
        seq.push(rng.gen_range(1 << 20, 1 << 21) as u32);
    }
    seq
}

/// PROPERTY: after any interleaving of insert / match / lock / unlock /
/// evict / reload, the radix tree's token counters equal the sum over live
/// nodes, parent-child links are consistent, and a locked path's deepest
/// node is never evicted.
#[test]
fn radix_invariants_under_random_ops() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        let mut tree = RadixTree::new();
        let mut locked: Vec<(Vec<usize>, Vec<Token>)> = Vec::new();
        let mut clockv = 0u64;
        for _op in 0..200 {
            clockv += 1;
            let now = Micros(clockv);
            match rng.gen_range(0, 10) {
                0..=3 => {
                    let seq = random_seq(&mut rng, 300);
                    let ins = tree.insert(&seq, now);
                    if rng.chance(0.4) && !ins.path.is_empty() {
                        tree.lock_path(&ins.path);
                        locked.push((ins.path.clone(), seq));
                    }
                }
                4..=5 => {
                    let seq = random_seq(&mut rng, 300);
                    let m = tree.match_prefix(&seq, now);
                    assert!(m.total() <= seq.len() as u64);
                }
                6 => {
                    if let Some((path, _)) = locked.pop() {
                        tree.unlock_path(&path);
                    }
                }
                7..=8 => {
                    let want = rng.gen_range(1, 2_000);
                    let policy = if rng.chance(0.5) {
                        EvictPolicy::Discard
                    } else {
                        EvictPolicy::OffloadToCpu
                    };
                    tree.evict(want, policy);
                }
                _ => {
                    let seq = random_seq(&mut rng, 300);
                    let m = tree.match_prefix(&seq, now);
                    if m.cpu_tokens > 0 {
                        tree.reload_path(&m.path, now);
                    }
                }
            }
            tree.check_invariants().unwrap_or_else(|e| {
                panic!("seed {seed}: invariant violated: {e}")
            });
            // Locked sequences must still fully match (their KV is pinned
            // on GPU or CPU, never dropped).
            for (_, seq) in &locked {
                let m = tree.match_prefix(seq, Micros(clockv));
                assert_eq!(
                    m.total(),
                    seq.len() as u64,
                    "seed {seed}: locked sequence lost cache"
                );
            }
        }
    }
}

/// PROPERTY: matched prefix length is exactly the longest common prefix
/// with some previously inserted sequence.
#[test]
fn radix_match_equals_longest_common_prefix() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(1000 + seed);
        let mut tree = RadixTree::new();
        let mut corpus: Vec<Vec<Token>> = Vec::new();
        for i in 0..30 {
            let seq = random_seq(&mut rng, 200);
            tree.insert(&seq, Micros(i));
            corpus.push(seq);
        }
        for _ in 0..30 {
            let probe = random_seq(&mut rng, 200);
            let expected = corpus
                .iter()
                .map(|s| {
                    s.iter()
                        .zip(&probe)
                        .take_while(|(a, b)| a == b)
                        .count() as u64
                })
                .max()
                .unwrap_or(0);
            let m = tree.match_prefix(&probe, Micros(999_999));
            assert_eq!(m.total(), expected, "seed {seed}");
        }
    }
}

/// PROPERTY: eviction frees exactly what the counters say and never makes
/// the tree unusable; fully unlocked trees evict to zero.
#[test]
fn eviction_is_complete_and_exact() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(2000 + seed);
        let mut tree = RadixTree::new();
        for i in 0..20 {
            tree.insert(&random_seq(&mut rng, 400), Micros(i));
        }
        let before = tree.gpu_tokens();
        let ev = tree.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, before, "seed {seed}");
        assert_eq!(tree.gpu_tokens(), 0);
        assert_eq!(tree.node_count(), 0);
        tree.check_invariants().unwrap();
        // Tree remains usable after total eviction.
        let seq = random_seq(&mut rng, 100);
        tree.insert(&seq, Micros(10_000));
        assert_eq!(tree.match_prefix(&seq, Micros(10_001)).total(), seq.len() as u64);
    }
}

/// PROPERTY: the engine's pool/tree/private accounting stays exact under
/// random multi-agent request streams with random pool sizes.
#[test]
fn engine_accounting_under_random_workloads() {
    use concur::config::{EngineConfig, EvictionMode};
    use concur::core::{AgentId, RequestId};
    use concur::costmodel::{ClusterSpec, CostModel, GpuSpec, ModelSpec};
    use concur::engine::{Request, SimEngine};

    for seed in 0..25u64 {
        let mut rng = Rng::new(3000 + seed);
        let pool = rng.gen_range(4_000, 60_000);
        let eviction = if rng.chance(0.5) {
            EvictionMode::Discard
        } else {
            EvictionMode::Offload
        };
        let cluster = ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), 4, 4);
        let mut engine = SimEngine::new(
            EngineConfig { eviction, hit_window: 8, ..EngineConfig::default() },
            CostModel::new(cluster),
        );
        engine.shrink_pool_for_tests(pool);

        let mut rid = 0u64;
        let mut now = Micros::ZERO;
        for _round in 0..4 {
            let n = rng.gen_range(1, 10) as usize;
            for _ in 0..n {
                let plen = rng.gen_range(16, 2_000);
                let glen = rng.gen_range(1, 120) as u32;
                let base = rng.gen_range(1 << 22, 1 << 24) as u32;
                engine.submit(Request {
                    id: RequestId(rid),
                    agent: AgentId(rid % 7),
                    prompt: (base..base + plen as u32).collect(),
                    gen: (0..glen).map(|k| (1 << 25) + rid as u32 * 256 + k).collect(),
                    prev_ctx: 0,
                    submitted_at: now,
                });
                rid += 1;
            }
            for _ in 0..20_000 {
                if !engine.has_work() {
                    break;
                }
                let out = engine.step(now);
                now += out.duration + Micros(1);
                engine.check_invariants().unwrap_or_else(|e| {
                    panic!("seed {seed} pool {pool}: {e}")
                });
            }
            assert!(!engine.has_work(), "seed {seed}: engine stuck");
        }
    }
}

/// PROPERTY: the slot manager conserves agents — every registered agent is
/// at all times in exactly one of {active, paused, fresh, released}.
#[test]
fn slot_manager_conserves_agents() {
    use concur::coordinator::slots::BoundaryDecision;
    use concur::coordinator::SlotManager;
    use concur::core::AgentId;
    use std::collections::HashSet;

    for seed in 0..40u64 {
        let mut rng = Rng::new(4000 + seed);
        let n = rng.gen_range(2, 40) as u64;
        let mut slots = SlotManager::new();
        let mut released: HashSet<AgentId> = HashSet::new();
        let mut active: HashSet<AgentId> = HashSet::new();
        for i in 0..n {
            slots.register(AgentId(i));
        }
        for _ in 0..200 {
            let window = rng.gen_range(1, n + 2) as usize;
            for a in slots.grant_up_to(window) {
                assert!(active.insert(a), "double-granted {a}");
            }
            // Random boundary events for active agents.
            let snapshot: Vec<AgentId> = active.iter().copied().collect();
            for a in snapshot {
                if released.contains(&a) {
                    continue;
                }
                match rng.gen_range(0, 4) {
                    0 => {
                        if slots.on_step_boundary(a, window) == BoundaryDecision::Paused
                        {
                            active.remove(&a);
                        }
                    }
                    1 => {
                        slots.release(a);
                        active.remove(&a);
                        released.insert(a);
                    }
                    _ => {}
                }
            }
            assert_eq!(slots.active_count(), active.len(), "seed {seed}");
            assert_eq!(
                slots.active_count() + slots.pending_count() + released.len(),
                n as usize,
                "seed {seed}: agents leaked"
            );
        }
    }
}

/// PROPERTY: JSON round-trips arbitrary generated values exactly.
#[test]
fn json_roundtrip_random_documents() {
    use concur::core::json::Value;
    use std::collections::BTreeMap;

    fn gen_value(rng: &mut Rng, depth: u32) -> Value {
        match if depth == 0 { rng.gen_range(0, 4) } else { rng.gen_range(0, 6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Number((rng.gen_range(0, 1 << 40) as f64) / 8.0),
            3 => Value::String(
                (0..rng.gen_range(0, 12))
                    .map(|_| {
                        char::from_u32(rng.gen_range(32, 1024) as u32).unwrap_or('x')
                    })
                    .collect(),
            ),
            4 => Value::Array(
                (0..rng.gen_range(0, 5))
                    .map(|_| gen_value(rng, depth - 1))
                    .collect(),
            ),
            _ => {
                let mut m = BTreeMap::new();
                for i in 0..rng.gen_range(0, 5) {
                    m.insert(format!("k{i}"), gen_value(rng, depth - 1));
                }
                Value::Object(m)
            }
        }
    }

    for seed in 0..200u64 {
        let mut rng = Rng::new(5000 + seed);
        let v = gen_value(&mut rng, 3);
        let compact = v.to_string_compact();
        let pretty = v.to_string_pretty();
        assert_eq!(Value::parse(&compact).unwrap(), v, "seed {seed}");
        assert_eq!(Value::parse(&pretty).unwrap(), v, "seed {seed}");
    }
}
