//! Property-based tests on the crate's core invariants.
//!
//! The vendored crate set has no proptest, so properties are checked with
//! seeded random-case sweeps (hundreds of cases per property, bit-stable
//! across runs).  Each property states the invariant it defends.

use concur::core::{Micros, Rng, Token};
use concur::engine::{EvictPolicy, KvLifetimePolicy, RadixTree};

/// Every KV lifetime policy, in declaration order.  The radix op-trace
/// suites below replay the *same* seeded traces under each policy:
/// stamping draws are consumed unconditionally (and are a no-op under
/// `Lru`), so the trace a seed produces is policy-independent while the
/// eviction order it exercises is not.
const LIFETIME_POLICIES: [KvLifetimePolicy; 3] = [
    KvLifetimePolicy::Lru,
    KvLifetimePolicy::StepsToExecution,
    KvLifetimePolicy::ToolTtl,
];

/// Random token sequence with a shared low-id prefix pool so sequences
/// overlap in interesting ways.
fn random_seq(rng: &mut Rng, max_len: usize) -> Vec<Token> {
    let len = rng.gen_range(1, max_len as u64 + 1) as usize;
    let share_prefix = rng.chance(0.6);
    let mut seq = Vec::with_capacity(len);
    if share_prefix {
        let plen = rng.gen_range(1, 64).min(len as u64) as usize;
        let family = rng.gen_range(0, 4) as u32;
        seq.extend((0..plen as u32).map(|i| family * 1000 + i));
    }
    while seq.len() < len {
        seq.push(rng.gen_range(1 << 20, 1 << 21) as u32);
    }
    seq
}

/// PROPERTY: after any interleaving of insert / match / lock / unlock /
/// evict / reload, the radix tree's token counters equal the sum over live
/// nodes, parent-child links are consistent, and a locked path's deepest
/// node is never evicted.
#[test]
fn radix_invariants_under_random_ops() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        let mut tree = RadixTree::new();
        let mut locked: Vec<(Vec<usize>, Vec<Token>)> = Vec::new();
        let mut clockv = 0u64;
        for _op in 0..200 {
            clockv += 1;
            let now = Micros(clockv);
            match rng.gen_range(0, 10) {
                0..=3 => {
                    let seq = random_seq(&mut rng, 300);
                    let ins = tree.insert(&seq, now);
                    if rng.chance(0.4) && !ins.path.is_empty() {
                        tree.lock_path(&ins.path);
                        locked.push((ins.path.clone(), seq));
                    }
                }
                4..=5 => {
                    let seq = random_seq(&mut rng, 300);
                    let m = tree.match_prefix(&seq, now);
                    assert!(m.total() <= seq.len() as u64);
                }
                6 => {
                    if let Some((path, _)) = locked.pop() {
                        tree.unlock_path(&path);
                    }
                }
                7..=8 => {
                    let want = rng.gen_range(1, 2_000);
                    let policy = if rng.chance(0.5) {
                        EvictPolicy::Discard
                    } else {
                        EvictPolicy::OffloadToCpu
                    };
                    tree.evict(want, policy);
                }
                _ => {
                    let seq = random_seq(&mut rng, 300);
                    let m = tree.match_prefix(&seq, now);
                    if m.cpu_tokens > 0 {
                        tree.reload_path(&m.path, now);
                    }
                }
            }
            tree.check_invariants().unwrap_or_else(|e| {
                panic!("seed {seed}: invariant violated: {e}")
            });
            // Locked sequences must still fully match (their KV is pinned
            // on GPU or CPU, never dropped).
            for (_, seq) in &locked {
                let m = tree.match_prefix(seq, Micros(clockv));
                assert_eq!(
                    m.total(),
                    seq.len() as u64,
                    "seed {seed}: locked sequence lost cache"
                );
            }
        }
    }
}

/// PROPERTY (satellite): the radix tree's invariants hold under long
/// random interleavings of *every* public mutator — `match_prefix`,
/// `insert_parts`, `lock_path`/`unlock_path`, `evict_at` (both residency
/// policies), `trim_cpu`, `reload_path`, `stamp_path_lifetime` —
/// **including the broadcast pin/demote ops** of the shared-prefix tier —
/// and under **every [`KvLifetimePolicy`]**, replaying the same 12-seed
/// op traces per policy.  `check_invariants()` runs after every op, and a
/// broadcast-pinned sequence must stay fully matchable (GPU or CPU,
/// never dropped) until its demotion, whatever the eviction order the
/// policy picks.  Fixed seed set (12 ≥ 8), so the CI run is
/// deterministic.
#[test]
fn radix_invariants_with_broadcast_ops() {
    for policy in LIFETIME_POLICIES {
        for seed in 0..12u64 {
            let mut rng = Rng::new(7000 + seed);
            let mut tree = RadixTree::with_policy(policy);
            let mut locked: Vec<Vec<usize>> = Vec::new();
            let mut broadcast: Vec<(Vec<usize>, Vec<Token>)> = Vec::new();
            let mut clockv = 0u64;
            for op in 0..250 {
                clockv += 1;
                let now = Micros(clockv);
                match rng.gen_range(0, 13) {
                    0..=2 => {
                        let seq = random_seq(&mut rng, 300);
                        let cut = rng.gen_range(0, seq.len() as u64 + 1) as usize;
                        let ins = tree.insert_parts(&seq[..cut], &seq[cut..], now);
                        if rng.chance(0.3) && !ins.path.is_empty() {
                            tree.lock_path(&ins.path);
                            locked.push(ins.path);
                        }
                    }
                    3 => {
                        // Broadcast-pin a freshly inserted sequence (the tier's
                        // install flow: insert, then pin the returned path).
                        if broadcast.len() < 6 {
                            let seq = random_seq(&mut rng, 300);
                            let ins = tree.insert(&seq, now);
                            assert!(!ins.path.is_empty());
                            tree.pin_broadcast(&ins.path);
                            broadcast.push((ins.path, seq));
                        }
                    }
                    4..=5 => {
                        let seq = random_seq(&mut rng, 300);
                        let m = tree.match_prefix(&seq, now);
                        assert!(m.total() <= seq.len() as u64);
                        assert!(m.broadcast_tokens <= m.total());
                    }
                    6 => {
                        if let Some(path) = locked.pop() {
                            tree.unlock_path(&path);
                        }
                    }
                    7 => {
                        // Demote in random order, not just LIFO.
                        if !broadcast.is_empty() {
                            let i = rng.gen_range(0, broadcast.len() as u64) as usize;
                            let (path, _) = broadcast.remove(i);
                            tree.demote_broadcast(&path);
                        }
                    }
                    8..=9 => {
                        let want = rng.gen_range(1, 2_000);
                        let ep = if rng.chance(0.5) {
                            EvictPolicy::Discard
                        } else {
                            EvictPolicy::OffloadToCpu
                        };
                        // Clocked form so `ToolTtl` exercises lazy pin
                        // expiry; identical to `evict` under `Lru`.
                        tree.evict_at(want, ep, now);
                    }
                    10 => {
                        tree.trim_cpu(rng.gen_range(0, 2_000));
                    }
                    11 => {
                        // Lifetime stamping, the engine's hint path.  The
                        // draws happen under every policy (keeping the
                        // trace policy-independent); the stamp itself is a
                        // no-op under `Lru`.
                        let seq = random_seq(&mut rng, 300);
                        let class = rng.gen_range(0, 1 << 20);
                        let pin = now + Micros(rng.gen_range(0, 3_000));
                        let m = tree.match_prefix(&seq, now);
                        tree.stamp_path_lifetime(&m.path, class, pin);
                    }
                    _ => {
                        let seq = random_seq(&mut rng, 300);
                        let m = tree.match_prefix(&seq, now);
                        if m.cpu_tokens > 0 {
                            tree.reload_path(&m.path, now);
                        }
                    }
                }
                tree.check_invariants().unwrap_or_else(|e| {
                    panic!("{policy:?} seed {seed} op {op}: invariant violated: {e}")
                });
                // Every pinned broadcast sequence must still fully match —
                // eviction and trimming may never touch covered nodes.
                for (_, seq) in &broadcast {
                    clockv += 1;
                    let m = tree.match_prefix(seq, Micros(clockv));
                    assert_eq!(
                        m.total(),
                        seq.len() as u64,
                        "{policy:?} seed {seed} op {op}: broadcast-pinned sequence lost cache"
                    );
                }
            }
            // Tear-down: demote and unlock everything, then the tree must be
            // fully reclaimable again — TTL pins shape the drain order but
            // never block it.
            while let Some((path, _)) = broadcast.pop() {
                tree.demote_broadcast(&path);
            }
            while let Some(path) = locked.pop() {
                tree.unlock_path(&path);
            }
            assert_eq!(
                tree.broadcast_tokens(),
                0,
                "{policy:?} seed {seed}: coverage must drain"
            );
            tree.evict(u64::MAX, EvictPolicy::Discard);
            tree.check_invariants().unwrap_or_else(|e| {
                panic!("{policy:?} seed {seed}: invariant violated after teardown: {e}")
            });
        }
    }
}

/// Slow-path reference for the intrusive LRU: the list must equal its
/// own contents sorted by the `(lifetime, last_access, version, id)`
/// eviction key (the lifetime component is constant 0 under `Lru`).
/// Set-equality plus this sortedness pins the exact eviction order the
/// lazy-heap predecessor produced — the safety net for the planned
/// ordered-index swap (ROADMAP "LRU stale re-entry cost").
fn assert_lru_matches_slow_order(tree: &RadixTree, ctx: &str) {
    let order = tree.lru_order_for_tests();
    let mut sorted = order.clone();
    sorted.sort_unstable_by_key(|&id| tree.lru_key_for_tests(id));
    assert_eq!(order, sorted, "{ctx}: intrusive LRU order != (stamp, version, id) sort");
}

/// PROPERTY (satellite, ROADMAP item): under a pause-heavy workload —
/// many paths locked for long stretches while fresher work churns, then
/// unlocked in random order so their stale stamps re-enter through
/// `lru_insert`'s backward walk — the eviction order always equals the
/// `(stamp, version, id)` order computed by the slow path.  This is the
/// regression net for swapping the backward walk for an ordered index.
#[test]
fn lru_stale_reentry_matches_slow_path_order() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(8000 + seed);
        let mut tree = RadixTree::new();
        let mut held: Vec<Vec<usize>> = Vec::new();
        let mut clockv = 0u64;
        for op in 0..400 {
            clockv += 1;
            match rng.gen_range(0, 10) {
                0..=3 => {
                    let seq = random_seq(&mut rng, 200);
                    let ins = tree.insert(&seq, Micros(clockv));
                    // Lock aggressively: locked paths are the paused
                    // agents whose stamps go stale.
                    if rng.chance(0.6) && !ins.path.is_empty() {
                        tree.lock_path(&ins.path);
                        held.push(ins.path);
                    }
                }
                4..=6 => {
                    // Unlock a *random* held path: its stamp is now far
                    // behind the tail, forcing the backward walk deep.
                    if !held.is_empty() {
                        let i = rng.gen_range(0, held.len() as u64) as usize;
                        let path = held.remove(i);
                        tree.unlock_path(&path);
                    }
                }
                7 => {
                    let seq = random_seq(&mut rng, 200);
                    tree.match_prefix(&seq, Micros(clockv));
                }
                8 => {
                    tree.evict(rng.gen_range(1, 500), EvictPolicy::Discard);
                }
                _ => {
                    // A long tool call: jump the clock so subsequently
                    // touched nodes are *much* fresher than held stamps.
                    clockv += 50_000;
                }
            }
            assert_lru_matches_slow_order(&tree, &format!("seed {seed} op {op}"));
            tree.check_invariants().unwrap_or_else(|e| {
                panic!("seed {seed} op {op}: invariant violated: {e}")
            });
        }
        // Release everything and drain: the head must stay the slow-path
        // minimum through the whole eviction sequence.
        while let Some(path) = held.pop() {
            tree.unlock_path(&path);
            assert_lru_matches_slow_order(&tree, &format!("seed {seed} final unlock"));
        }
        loop {
            assert_lru_matches_slow_order(&tree, &format!("seed {seed} drain"));
            if tree.lru_order_for_tests().is_empty() {
                break;
            }
            tree.evict(1, EvictPolicy::Discard);
        }
        tree.check_invariants().unwrap();
    }
}

/// PROPERTY: matched prefix length is exactly the longest common prefix
/// with some previously inserted sequence.
#[test]
fn radix_match_equals_longest_common_prefix() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(1000 + seed);
        let mut tree = RadixTree::new();
        let mut corpus: Vec<Vec<Token>> = Vec::new();
        for i in 0..30 {
            let seq = random_seq(&mut rng, 200);
            tree.insert(&seq, Micros(i));
            corpus.push(seq);
        }
        for _ in 0..30 {
            let probe = random_seq(&mut rng, 200);
            let expected = corpus
                .iter()
                .map(|s| {
                    s.iter()
                        .zip(&probe)
                        .take_while(|(a, b)| a == b)
                        .count() as u64
                })
                .max()
                .unwrap_or(0);
            let m = tree.match_prefix(&probe, Micros(999_999));
            assert_eq!(m.total(), expected, "seed {seed}");
        }
    }
}

/// PROPERTY: eviction frees exactly what the counters say and never makes
/// the tree unusable; fully unlocked trees evict to zero.
#[test]
fn eviction_is_complete_and_exact() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(2000 + seed);
        let mut tree = RadixTree::new();
        for i in 0..20 {
            tree.insert(&random_seq(&mut rng, 400), Micros(i));
        }
        let before = tree.gpu_tokens();
        let ev = tree.evict(u64::MAX, EvictPolicy::Discard);
        assert_eq!(ev.freed_gpu_tokens, before, "seed {seed}");
        assert_eq!(tree.gpu_tokens(), 0);
        assert_eq!(tree.node_count(), 0);
        tree.check_invariants().unwrap();
        // Tree remains usable after total eviction.
        let seq = random_seq(&mut rng, 100);
        tree.insert(&seq, Micros(10_000));
        assert_eq!(tree.match_prefix(&seq, Micros(10_001)).total(), seq.len() as u64);
    }
}

/// Pre-arena radix tree, embedded verbatim as a behavioral oracle: per-node
/// `Vec<Token>` edge labels and the lazy version-stamped `BinaryHeap` LRU.
/// The production tree (arena + intrusive LRU list) must reproduce its
/// observable behavior *exactly* — eviction order included — so that the
/// perf rewrite cannot silently change simulation results.
mod reference {
    use std::cmp::Reverse;
    use std::collections::{BinaryHeap, HashMap};

    use concur::core::{Micros, Token};

    pub type NodeId = usize;

    const ROOT: NodeId = 0;

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Residency {
        Gpu,
        Cpu,
    }

    #[derive(Debug)]
    struct Node {
        key: Vec<Token>,
        children: HashMap<Token, NodeId>,
        parent: NodeId,
        ref_count: u32,
        pin_count: u32,
        last_access: Micros,
        residency: Residency,
        alive: bool,
        version: u64,
    }

    impl Node {
        fn tokens(&self) -> u64 {
            self.key.len() as u64
        }
    }

    #[derive(Debug, Clone, Default)]
    pub struct MatchResult {
        pub path: Vec<NodeId>,
        pub gpu_tokens: u64,
        pub cpu_tokens: u64,
    }

    impl MatchResult {
        pub fn total(&self) -> u64 {
            self.gpu_tokens + self.cpu_tokens
        }
    }

    #[derive(Debug, Clone, Default)]
    pub struct InsertResult {
        pub path: Vec<NodeId>,
        pub new_gpu_tokens: u64,
        pub cpu_tokens: u64,
    }

    #[derive(Debug, Clone, Default)]
    pub struct EvictResult {
        pub freed_gpu_tokens: u64,
        pub offloaded_tokens: u64,
        pub discarded_tokens: u64,
        pub nodes: usize,
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum EvictPolicy {
        Discard,
        OffloadToCpu,
    }

    pub struct RadixTree {
        nodes: Vec<Node>,
        free_slots: Vec<NodeId>,
        gpu_tokens: u64,
        cpu_tokens: u64,
        pinned_gpu_tokens: u64,
        lru: BinaryHeap<Reverse<(Micros, u64, NodeId)>>,
    }

    impl RadixTree {
        pub fn new() -> RadixTree {
            let root = Node {
                key: Vec::new(),
                children: HashMap::new(),
                parent: ROOT,
                ref_count: 1,
                pin_count: 0,
                last_access: Micros::ZERO,
                residency: Residency::Gpu,
                alive: true,
                version: 0,
            };
            RadixTree {
                nodes: vec![root],
                free_slots: Vec::new(),
                gpu_tokens: 0,
                cpu_tokens: 0,
                pinned_gpu_tokens: 0,
                lru: BinaryHeap::new(),
            }
        }

        pub fn gpu_tokens(&self) -> u64 {
            self.gpu_tokens
        }

        pub fn cpu_tokens(&self) -> u64 {
            self.cpu_tokens
        }

        pub fn node_count(&self) -> usize {
            self.nodes.iter().filter(|n| n.alive).count() - 1
        }

        pub fn evictable_gpu_tokens(&self) -> u64 {
            self.gpu_tokens - self.pinned_gpu_tokens
        }

        fn alloc_node(&mut self, node: Node) -> NodeId {
            if let Some(id) = self.free_slots.pop() {
                self.nodes[id] = node;
                id
            } else {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }

        fn touch(&mut self, id: NodeId, now: Micros) {
            let node = &mut self.nodes[id];
            node.last_access = now;
            node.version += 1;
        }

        fn is_gpu_leaf(&self, id: NodeId) -> bool {
            self.nodes[id]
                .children
                .values()
                .all(|&c| self.nodes[c].residency == Residency::Cpu)
        }

        fn push_candidate(&mut self, id: NodeId) {
            if id == ROOT {
                return;
            }
            let n = &self.nodes[id];
            if n.alive
                && n.ref_count == 0
                && n.residency == Residency::Gpu
                && self.is_gpu_leaf(id)
            {
                self.lru.push(Reverse((n.last_access, n.version, id)));
            }
        }

        fn split(&mut self, id: NodeId, at: usize) -> NodeId {
            let (upper_key, parent, last_access, residency) = {
                let n = &mut self.nodes[id];
                let upper_key: Vec<Token> = n.key[..at].to_vec();
                let rest: Vec<Token> = n.key[at..].to_vec();
                n.key = rest;
                (upper_key, n.parent, n.last_access, n.residency)
            };
            let first_upper = upper_key[0];
            let lower_pins = self.nodes[id].pin_count;
            let upper = self.alloc_node(Node {
                key: upper_key,
                children: HashMap::new(),
                parent,
                ref_count: 0,
                pin_count: lower_pins,
                last_access,
                residency,
                alive: true,
                version: 0,
            });
            let first_lower = self.nodes[id].key[0];
            self.nodes[upper].children.insert(first_lower, id);
            self.nodes[id].parent = upper;
            self.nodes[parent].children.insert(first_upper, upper);
            upper
        }

        pub fn match_prefix(&mut self, tokens: &[Token], now: Micros) -> MatchResult {
            let mut result = MatchResult::default();
            let mut cur = ROOT;
            let mut pos = 0usize;
            while pos < tokens.len() {
                let Some(&child) = self.nodes[cur].children.get(&tokens[pos]) else {
                    break;
                };
                let klen = self.nodes[child].key.len();
                let maxcmp = klen.min(tokens.len() - pos);
                let same = {
                    let key = &self.nodes[child].key;
                    if key[..maxcmp] == tokens[pos..pos + maxcmp] {
                        maxcmp
                    } else {
                        key[..maxcmp]
                            .iter()
                            .zip(&tokens[pos..pos + maxcmp])
                            .take_while(|(a, b)| a == b)
                            .count()
                    }
                };
                if same == 0 {
                    break;
                }
                let matched_node = if same < klen {
                    self.split(child, same)
                } else {
                    child
                };
                self.touch(matched_node, now);
                match self.nodes[matched_node].residency {
                    Residency::Gpu => result.gpu_tokens += same as u64,
                    Residency::Cpu => result.cpu_tokens += same as u64,
                }
                result.path.push(matched_node);
                pos += same;
                cur = matched_node;
                if same < klen {
                    break;
                }
            }
            result
        }

        pub fn insert(&mut self, tokens: &[Token], now: Micros) -> InsertResult {
            let m = self.match_prefix(tokens, now);
            let matched = m.total() as usize;
            let mut path = m.path;
            let cur = path.last().copied().unwrap_or(ROOT);
            let mut new_gpu = 0u64;
            if matched < tokens.len() {
                let rest: Vec<Token> = tokens[matched..].to_vec();
                new_gpu = rest.len() as u64;
                let first = rest[0];
                let leaf = self.alloc_node(Node {
                    key: rest,
                    children: HashMap::new(),
                    parent: cur,
                    ref_count: 0,
                    pin_count: 0,
                    last_access: now,
                    residency: Residency::Gpu,
                    alive: true,
                    version: 0,
                });
                self.nodes[cur].children.insert(first, leaf);
                self.gpu_tokens += new_gpu;
                path.push(leaf);
                self.push_candidate(leaf);
            }
            InsertResult { path, new_gpu_tokens: new_gpu, cpu_tokens: m.cpu_tokens }
        }

        pub fn lock_path(&mut self, path: &[NodeId]) {
            if let Some(&last) = path.last() {
                self.nodes[last].ref_count += 1;
                let mut id = last;
                while id != ROOT {
                    let n = &mut self.nodes[id];
                    n.pin_count += 1;
                    if n.pin_count == 1 && n.residency == Residency::Gpu {
                        self.pinned_gpu_tokens += n.key.len() as u64;
                    }
                    id = n.parent;
                }
            }
        }

        pub fn unlock_path(&mut self, path: &[NodeId]) {
            if let Some(&last) = path.last() {
                self.nodes[last].ref_count -= 1;
                let mut id = last;
                while id != ROOT {
                    let n = &mut self.nodes[id];
                    n.pin_count -= 1;
                    if n.pin_count == 0 && n.residency == Residency::Gpu {
                        self.pinned_gpu_tokens -= n.key.len() as u64;
                    }
                    id = n.parent;
                }
                self.push_candidate(last);
            }
        }

        pub fn evict(&mut self, want: u64, policy: EvictPolicy) -> EvictResult {
            let mut out = EvictResult::default();
            while out.freed_gpu_tokens < want {
                let Some(Reverse((stamp, version, id))) = self.lru.pop() else {
                    break;
                };
                let valid = {
                    let n = &self.nodes[id];
                    n.alive
                        && n.ref_count == 0
                        && n.residency == Residency::Gpu
                        && n.version == version
                        && n.last_access == stamp
                } && self.is_gpu_leaf(id);
                if !valid {
                    continue;
                }
                if policy == EvictPolicy::Discard && !self.nodes[id].children.is_empty()
                {
                    continue;
                }
                let tokens = self.nodes[id].tokens();
                out.freed_gpu_tokens += tokens;
                out.nodes += 1;
                self.gpu_tokens -= tokens;
                match policy {
                    EvictPolicy::Discard => {
                        out.discarded_tokens += tokens;
                        self.remove_leaf(id);
                    }
                    EvictPolicy::OffloadToCpu => {
                        out.offloaded_tokens += tokens;
                        self.cpu_tokens += tokens;
                        let n = &mut self.nodes[id];
                        if n.pin_count > 0 {
                            self.pinned_gpu_tokens -= tokens;
                        }
                        let n = &mut self.nodes[id];
                        n.residency = Residency::Cpu;
                        n.version += 1;
                        let parent = self.nodes[id].parent;
                        self.push_candidate(parent);
                    }
                }
            }
            out
        }

        fn remove_leaf(&mut self, id: NodeId) {
            let parent = self.nodes[id].parent;
            let first = self.nodes[id].key[0];
            self.nodes[parent].children.remove(&first);
            self.nodes[id].alive = false;
            self.nodes[id].key = Vec::new();
            self.free_slots.push(id);
            self.push_candidate(parent);
        }

        pub fn trim_cpu(&mut self, limit: u64) -> u64 {
            if self.cpu_tokens <= limit {
                return 0;
            }
            let mut dropped = 0u64;
            let mut cpu_leaves: Vec<(Micros, NodeId)> = self
                .nodes
                .iter()
                .enumerate()
                .filter(|(id, n)| {
                    *id != ROOT
                        && n.alive
                        && n.residency == Residency::Cpu
                        && n.children.is_empty()
                        && n.ref_count == 0
                })
                .map(|(id, n)| (n.last_access, id))
                .collect();
            cpu_leaves.sort_unstable();
            for (_, id) in cpu_leaves {
                if self.cpu_tokens <= limit {
                    break;
                }
                let tokens = self.nodes[id].tokens();
                self.cpu_tokens -= tokens;
                dropped += tokens;
                self.remove_leaf(id);
            }
            dropped
        }

        pub fn reload_path(&mut self, path: &[NodeId], now: Micros) -> u64 {
            let mut promoted = 0u64;
            for &id in path {
                let n = &mut self.nodes[id];
                if n.alive && n.residency == Residency::Cpu {
                    n.residency = Residency::Gpu;
                    n.last_access = now;
                    n.version += 1;
                    promoted += n.key.len() as u64;
                    if n.pin_count > 0 {
                        self.pinned_gpu_tokens += n.key.len() as u64;
                    }
                }
            }
            self.cpu_tokens -= promoted;
            self.gpu_tokens += promoted;
            promoted
        }
    }
}

/// PROPERTY (differential): the arena + intrusive-LRU tree is observably
/// identical to the pre-rewrite implementation — same match/insert/evict/
/// reload/trim token counts, same path lengths, same global counters —
/// under arbitrary interleavings of every operation, in both eviction
/// policies.  Inserts randomly go through `insert_parts` to also pin the
/// two-slice insert path to the concatenated-insert semantics.
#[test]
fn arena_tree_matches_reference_implementation() {
    use concur::engine::RadixTree as NewTree;

    use crate::reference::RadixTree as RefTree;

    for seed in 0..40u64 {
        let mut rng = Rng::new(9000 + seed);
        let mut new_t = NewTree::new();
        let mut ref_t = RefTree::new();
        // Parallel lock stacks: each implementation locks its own node ids.
        let mut locked: Vec<(Vec<usize>, Vec<usize>)> = Vec::new();
        let mut clockv = 0u64;
        for op in 0..300 {
            clockv += 1;
            let now = Micros(clockv);
            match rng.gen_range(0, 12) {
                0..=3 => {
                    let seq = random_seq(&mut rng, 300);
                    let cut = rng.gen_range(0, seq.len() as u64 + 1) as usize;
                    let a = new_t.insert_parts(&seq[..cut], &seq[cut..], now);
                    let b = ref_t.insert(&seq, now);
                    assert_eq!(a.new_gpu_tokens, b.new_gpu_tokens, "seed {seed} op {op}");
                    assert_eq!(a.cpu_tokens, b.cpu_tokens, "seed {seed} op {op}");
                    assert_eq!(a.path.len(), b.path.len(), "seed {seed} op {op}");
                    if rng.chance(0.35) && !a.path.is_empty() {
                        new_t.lock_path(&a.path);
                        ref_t.lock_path(&b.path);
                        locked.push((a.path.clone(), b.path.clone()));
                    }
                }
                4..=5 => {
                    let seq = random_seq(&mut rng, 300);
                    let a = new_t.match_prefix(&seq, now);
                    let b = ref_t.match_prefix(&seq, now);
                    assert_eq!(a.gpu_tokens, b.gpu_tokens, "seed {seed} op {op}");
                    assert_eq!(a.cpu_tokens, b.cpu_tokens, "seed {seed} op {op}");
                    assert_eq!(a.path.len(), b.path.len(), "seed {seed} op {op}");
                }
                6 => {
                    if let Some((pa, pb)) = locked.pop() {
                        new_t.unlock_path(&pa);
                        ref_t.unlock_path(&pb);
                    }
                }
                7..=9 => {
                    let want = rng.gen_range(1, 2_000);
                    let (policy_new, policy_ref) = if rng.chance(0.5) {
                        (
                            concur::engine::EvictPolicy::Discard,
                            reference::EvictPolicy::Discard,
                        )
                    } else {
                        (
                            concur::engine::EvictPolicy::OffloadToCpu,
                            reference::EvictPolicy::OffloadToCpu,
                        )
                    };
                    let a = new_t.evict(want, policy_new);
                    let b = ref_t.evict(want, policy_ref);
                    assert_eq!(
                        a.freed_gpu_tokens, b.freed_gpu_tokens,
                        "seed {seed} op {op}: eviction diverged"
                    );
                    assert_eq!(a.offloaded_tokens, b.offloaded_tokens, "seed {seed} op {op}");
                    assert_eq!(a.discarded_tokens, b.discarded_tokens, "seed {seed} op {op}");
                    assert_eq!(a.nodes, b.nodes, "seed {seed} op {op}");
                }
                10 => {
                    let limit = rng.gen_range(0, 2_000);
                    let a = new_t.trim_cpu(limit);
                    let b = ref_t.trim_cpu(limit);
                    assert_eq!(a, b, "seed {seed} op {op}: trim diverged");
                }
                _ => {
                    let seq = random_seq(&mut rng, 300);
                    let a = new_t.match_prefix(&seq, now);
                    let b = ref_t.match_prefix(&seq, now);
                    assert_eq!(a.cpu_tokens, b.cpu_tokens, "seed {seed} op {op}");
                    if a.cpu_tokens > 0 {
                        let pa = new_t.reload_path(&a.path, now);
                        let pb = ref_t.reload_path(&b.path, now);
                        assert_eq!(pa, pb, "seed {seed} op {op}: reload diverged");
                    }
                }
            }
            assert_eq!(new_t.gpu_tokens(), ref_t.gpu_tokens(), "seed {seed} op {op}");
            assert_eq!(new_t.cpu_tokens(), ref_t.cpu_tokens(), "seed {seed} op {op}");
            assert_eq!(new_t.node_count(), ref_t.node_count(), "seed {seed} op {op}");
            assert_eq!(
                new_t.evictable_gpu_tokens(),
                ref_t.evictable_gpu_tokens(),
                "seed {seed} op {op}"
            );
            new_t.check_invariants().unwrap_or_else(|e| {
                panic!("seed {seed} op {op}: invariant violated: {e}")
            });
        }
        // Full drain must agree too (including the parked-node quirk:
        // touched-but-never-repushed candidates survive in both).
        while let Some((pa, pb)) = locked.pop() {
            new_t.unlock_path(&pa);
            ref_t.unlock_path(&pb);
        }
        let a = new_t.evict(u64::MAX, concur::engine::EvictPolicy::Discard);
        let b = ref_t.evict(u64::MAX, reference::EvictPolicy::Discard);
        assert_eq!(a.freed_gpu_tokens, b.freed_gpu_tokens, "seed {seed}: final drain");
        assert_eq!(new_t.node_count(), ref_t.node_count(), "seed {seed}: final drain");
    }
}

/// PROPERTY (satellite): tree invariants hold with **generational arena
/// compaction** forced mid-sequence, across every public mutator
/// including the broadcast pin/demote pair and lifetime stamping, under
/// **every [`KvLifetimePolicy`]** (same 12-seed traces per policy).
/// `check_invariants` runs after every op and after every forced
/// compaction, and compaction must leave the arena at exactly the live
/// token count while every pinned sequence stays fully matchable.
#[test]
fn radix_invariants_with_mid_sequence_compaction() {
    for policy in LIFETIME_POLICIES {
        for seed in 0..12u64 {
            let mut rng = Rng::new(11_000 + seed);
            let mut tree = RadixTree::with_policy(policy);
            let mut locked: Vec<Vec<usize>> = Vec::new();
            let mut broadcast: Vec<(Vec<usize>, Vec<Token>)> = Vec::new();
            let mut clockv = 0u64;
            for op in 0..250 {
                clockv += 1;
                let now = Micros(clockv);
                match rng.gen_range(0, 14) {
                    0..=2 => {
                        let seq = random_seq(&mut rng, 300);
                        let cut = rng.gen_range(0, seq.len() as u64 + 1) as usize;
                        let ins = tree.insert_parts(&seq[..cut], &seq[cut..], now);
                        if rng.chance(0.3) && !ins.path.is_empty() {
                            tree.lock_path(&ins.path);
                            locked.push(ins.path);
                        }
                    }
                    3 => {
                        if broadcast.len() < 6 {
                            let seq = random_seq(&mut rng, 300);
                            let ins = tree.insert(&seq, now);
                            assert!(!ins.path.is_empty());
                            tree.pin_broadcast(&ins.path);
                            broadcast.push((ins.path, seq));
                        }
                    }
                    4..=5 => {
                        let seq = random_seq(&mut rng, 300);
                        let m = tree.match_prefix(&seq, now);
                        assert!(m.total() <= seq.len() as u64);
                    }
                    6 => {
                        if let Some(path) = locked.pop() {
                            tree.unlock_path(&path);
                        }
                    }
                    7 => {
                        if !broadcast.is_empty() {
                            let i = rng.gen_range(0, broadcast.len() as u64) as usize;
                            let (path, _) = broadcast.remove(i);
                            tree.demote_broadcast(&path);
                        }
                    }
                    8..=9 => {
                        let want = rng.gen_range(1, 2_000);
                        let ep = if rng.chance(0.5) {
                            EvictPolicy::Discard
                        } else {
                            EvictPolicy::OffloadToCpu
                        };
                        tree.evict_at(want, ep, now);
                    }
                    10 => {
                        tree.trim_cpu(rng.gen_range(0, 2_000));
                    }
                    11 => {
                        // The compaction op in the mix: force one at an
                        // arbitrary point, regardless of slack.
                        tree.compact_arena();
                        assert_eq!(
                            tree.arena_len() as u64,
                            tree.gpu_tokens() + tree.cpu_tokens(),
                            "{policy:?} seed {seed} op {op}: compaction left slack"
                        );
                        tree.check_invariants().unwrap_or_else(|e| {
                            panic!(
                                "{policy:?} seed {seed} op {op}: invariant after compaction: {e}"
                            )
                        });
                    }
                    12 => {
                        // Lifetime stamping (no-op under `Lru`; the draws
                        // are policy-independent either way).
                        let seq = random_seq(&mut rng, 300);
                        let class = rng.gen_range(0, 1 << 20);
                        let pin = now + Micros(rng.gen_range(0, 3_000));
                        let m = tree.match_prefix(&seq, now);
                        tree.stamp_path_lifetime(&m.path, class, pin);
                    }
                    _ => {
                        let seq = random_seq(&mut rng, 300);
                        let m = tree.match_prefix(&seq, now);
                        if m.cpu_tokens > 0 {
                            tree.reload_path(&m.path, now);
                        }
                    }
                }
                tree.check_invariants().unwrap_or_else(|e| {
                    panic!("{policy:?} seed {seed} op {op}: invariant violated: {e}")
                });
                for (_, seq) in &broadcast {
                    clockv += 1;
                    let m = tree.match_prefix(seq, Micros(clockv));
                    assert_eq!(
                        m.total(),
                        seq.len() as u64,
                        "{policy:?} seed {seed} op {op}: broadcast-pinned sequence lost cache"
                    );
                }
            }
            // Tear down, compact once more, and drain.
            while let Some((path, _)) = broadcast.pop() {
                tree.demote_broadcast(&path);
            }
            while let Some(path) = locked.pop() {
                tree.unlock_path(&path);
            }
            tree.compact_arena();
            tree.check_invariants().unwrap();
            tree.evict(u64::MAX, EvictPolicy::Discard);
            tree.check_invariants().unwrap();
        }
    }
}

/// PROPERTY (differential): a compacting tree is observably
/// bit-identical to a non-compacting oracle (`set_auto_compaction(false)`
/// — the pre-compaction append-only behavior) on random
/// match/insert/evict/reload/trim/stamp traces, under **every
/// [`KvLifetimePolicy`]**.  Forced compactions are sprinkled through the
/// trace on the compacting side only: compaction rewrites arena offsets,
/// never behavior — and in particular never the policy-ordered eviction
/// queue, which is asserted entry-for-entry after every op.
#[test]
fn compacting_tree_matches_non_compacting_oracle() {
    for policy in LIFETIME_POLICIES {
        for seed in 0..25u64 {
            let mut rng = Rng::new(12_000 + seed);
            let mut compacting = RadixTree::with_policy(policy);
            let mut oracle = RadixTree::with_policy(policy);
            oracle.set_auto_compaction(false);
            let mut locked: Vec<Vec<usize>> = Vec::new();
            let mut clockv = 0u64;
            for op in 0..300 {
                clockv += 1;
                let now = Micros(clockv);
                match rng.gen_range(0, 13) {
                    0..=3 => {
                        let seq = random_seq(&mut rng, 300);
                        let cut = rng.gen_range(0, seq.len() as u64 + 1) as usize;
                        let a = compacting.insert_parts(&seq[..cut], &seq[cut..], now);
                        let b = oracle.insert_parts(&seq[..cut], &seq[cut..], now);
                        assert_eq!(a.new_gpu_tokens, b.new_gpu_tokens, "seed {seed} op {op}");
                        assert_eq!(a.path, b.path, "seed {seed} op {op}");
                        if rng.chance(0.35) && !a.path.is_empty() {
                            compacting.lock_path(&a.path);
                            oracle.lock_path(&b.path);
                            locked.push(a.path);
                        }
                    }
                    4..=5 => {
                        let seq = random_seq(&mut rng, 300);
                        let a = compacting.match_prefix(&seq, now);
                        let b = oracle.match_prefix(&seq, now);
                        assert_eq!(a.gpu_tokens, b.gpu_tokens, "seed {seed} op {op}");
                        assert_eq!(a.cpu_tokens, b.cpu_tokens, "seed {seed} op {op}");
                        assert_eq!(a.path, b.path, "seed {seed} op {op}");
                    }
                    6 => {
                        if let Some(path) = locked.pop() {
                            compacting.unlock_path(&path);
                            oracle.unlock_path(&path);
                        }
                    }
                    7..=8 => {
                        let want = rng.gen_range(1, 2_000);
                        let ep = if rng.chance(0.5) {
                            EvictPolicy::Discard
                        } else {
                            EvictPolicy::OffloadToCpu
                        };
                        let a = compacting.evict_at(want, ep, now);
                        let b = oracle.evict_at(want, ep, now);
                        assert_eq!(
                            a.freed_gpu_tokens, b.freed_gpu_tokens,
                            "seed {seed} op {op}: eviction diverged"
                        );
                        assert_eq!(a.discarded_tokens, b.discarded_tokens, "seed {seed} op {op}");
                        assert_eq!(a.offloaded_tokens, b.offloaded_tokens, "seed {seed} op {op}");
                        assert_eq!(a.nodes, b.nodes, "seed {seed} op {op}");
                    }
                    9 => {
                        let limit = rng.gen_range(0, 2_000);
                        assert_eq!(
                            compacting.trim_cpu(limit),
                            oracle.trim_cpu(limit),
                            "seed {seed} op {op}: trim diverged"
                        );
                    }
                    10 => {
                        // Compacting side only: the divergence injection.
                        compacting.compact_arena();
                    }
                    11 => {
                        // Same stamp on both sides (no-op under `Lru`):
                        // reordering the eviction queue must commute with
                        // compaction like every other mutator.
                        let seq = random_seq(&mut rng, 300);
                        let class = rng.gen_range(0, 1 << 20);
                        let pin = now + Micros(rng.gen_range(0, 3_000));
                        let a = compacting.match_prefix(&seq, now);
                        let b = oracle.match_prefix(&seq, now);
                        assert_eq!(a.path, b.path, "seed {seed} op {op}");
                        compacting.stamp_path_lifetime(&a.path, class, pin);
                        oracle.stamp_path_lifetime(&b.path, class, pin);
                    }
                    _ => {
                        let seq = random_seq(&mut rng, 300);
                        let a = compacting.match_prefix(&seq, now);
                        let b = oracle.match_prefix(&seq, now);
                        assert_eq!(a.path, b.path, "seed {seed} op {op}");
                        if a.cpu_tokens > 0 {
                            let pa = compacting.reload_path(&a.path, now);
                            let pb = oracle.reload_path(&b.path, now);
                            assert_eq!(pa, pb, "seed {seed} op {op}: reload diverged");
                        }
                    }
                }
                assert_eq!(compacting.gpu_tokens(), oracle.gpu_tokens(), "seed {seed} op {op}");
                assert_eq!(compacting.cpu_tokens(), oracle.cpu_tokens(), "seed {seed} op {op}");
                assert_eq!(compacting.node_count(), oracle.node_count(), "seed {seed} op {op}");
                assert_eq!(
                    compacting.lru_order_for_tests(),
                    oracle.lru_order_for_tests(),
                    "{policy:?} seed {seed} op {op}: eviction order diverged"
                );
                // The compacting side must stay bounded; the oracle's arena
                // only ever grows.
                assert!(
                    compacting.arena_len() <= oracle.arena_len(),
                    "seed {seed} op {op}: compaction grew the arena"
                );
                compacting.check_invariants().unwrap_or_else(|e| {
                    panic!("{policy:?} seed {seed} op {op}: compacting invariant: {e}")
                });
                oracle.check_invariants().unwrap_or_else(|e| {
                    panic!("{policy:?} seed {seed} op {op}: oracle invariant: {e}")
                });
            }
        }
    }
}

/// PROPERTY: `run_jobs_parallel` returns bit-identical `RunResult`s to
/// serial execution on randomized seeded workloads — the parallel sweep
/// harness must never change simulation outcomes.
#[test]
fn parallel_sweep_is_bit_identical_on_random_jobs() {
    use concur::config::{
        AimdParams, EngineConfig, EvictionMode, JobConfig, SchedulerKind,
        TopologyConfig, WorkloadConfig,
    };
    use concur::config::presets;
    use concur::driver::{run_jobs, run_jobs_parallel_with};

    let mut rng = Rng::new(0xC0_FFEE);
    let jobs: Vec<JobConfig> = (0..6)
        .map(|i| {
            let scheduler = match i % 4 {
                0 => SchedulerKind::Uncontrolled,
                1 => SchedulerKind::Concur(AimdParams::default()),
                2 => SchedulerKind::AgentCap(rng.gen_range(2, 6) as usize),
                _ => SchedulerKind::RequestCap(rng.gen_range(2, 6) as usize),
            };
            let eviction = if rng.chance(0.5) {
                EvictionMode::Discard
            } else {
                EvictionMode::Offload
            };
            JobConfig {
                cluster: presets::qwen3_cluster(8),
                engine: EngineConfig { eviction, hit_window: 8, ..EngineConfig::default() },
                workload: WorkloadConfig {
                    n_agents: rng.gen_range(4, 10) as usize,
                    steps_min: 2,
                    steps_max: 3,
                    seed: rng.gen_range(1, 1_000),
                    ..WorkloadConfig::default()
                },
                scheduler,
                topology: TopologyConfig::default(),
            }
        })
        .collect();

    let serial = run_jobs(&jobs);
    for threads in [2usize, 4, 8] {
        let parallel = run_jobs_parallel_with(&jobs, threads);
        for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
            let (s, p) = (s.as_ref().unwrap(), p.as_ref().unwrap());
            assert_eq!(s.total_time, p.total_time, "job {i} ({} threads)", threads);
            assert_eq!(s.hit_rate, p.hit_rate, "job {i}");
            assert_eq!(s.counters.decode_tokens, p.counters.decode_tokens, "job {i}");
            assert_eq!(s.counters.prefill_tokens, p.counters.prefill_tokens, "job {i}");
            assert_eq!(s.counters.evicted_tokens, p.counters.evicted_tokens, "job {i}");
            assert_eq!(s.counters.preemptions, p.counters.preemptions, "job {i}");
            assert_eq!(s.engine_steps, p.engine_steps, "job {i}");
            assert_eq!(s.agents_finished, p.agents_finished, "job {i}");
        }
    }
}

/// PROPERTY: the engine's pool/tree/private accounting stays exact under
/// random multi-agent request streams with random pool sizes.
#[test]
fn engine_accounting_under_random_workloads() {
    use concur::config::{EngineConfig, EvictionMode};
    use concur::core::{AgentId, RequestId};
    use concur::costmodel::{ClusterSpec, CostModel, GpuSpec, ModelSpec};
    use concur::engine::{Request, SimEngine};

    for seed in 0..25u64 {
        let mut rng = Rng::new(3000 + seed);
        let pool = rng.gen_range(4_000, 60_000);
        let eviction = if rng.chance(0.5) {
            EvictionMode::Discard
        } else {
            EvictionMode::Offload
        };
        let cluster = ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), 4, 4);
        let mut engine = SimEngine::new(
            EngineConfig { eviction, hit_window: 8, ..EngineConfig::default() },
            CostModel::new(cluster),
        );
        engine.shrink_pool_for_tests(pool);

        let mut rid = 0u64;
        let mut now = Micros::ZERO;
        for _round in 0..4 {
            let n = rng.gen_range(1, 10) as usize;
            for _ in 0..n {
                let plen = rng.gen_range(16, 2_000);
                let glen = rng.gen_range(1, 120) as u32;
                let base = rng.gen_range(1 << 22, 1 << 24) as u32;
                engine.submit(Request {
                    id: RequestId(rid),
                    agent: AgentId(rid % 7),
                    prompt: (base..base + plen as u32).collect(),
                    gen: (0..glen).map(|k| (1 << 25) + rid as u32 * 256 + k).collect(),
                    prev_ctx: 0,
                    submitted_at: now,
                });
                rid += 1;
            }
            for _ in 0..20_000 {
                if !engine.has_work() {
                    break;
                }
                let out = engine.step(now);
                now += out.duration + Micros(1);
                engine.check_invariants().unwrap_or_else(|e| {
                    panic!("seed {seed} pool {pool}: {e}")
                });
            }
            assert!(!engine.has_work(), "seed {seed}: engine stuck");
        }
    }
}

/// PROPERTY: the slot manager conserves agents — every registered agent is
/// at all times in exactly one of {active, paused, fresh, released}.
#[test]
fn slot_manager_conserves_agents() {
    use concur::coordinator::slots::BoundaryDecision;
    use concur::coordinator::SlotManager;
    use concur::core::AgentId;
    use std::collections::HashSet;

    for seed in 0..40u64 {
        let mut rng = Rng::new(4000 + seed);
        let n = rng.gen_range(2, 40) as u64;
        let mut slots = SlotManager::new();
        let mut released: HashSet<AgentId> = HashSet::new();
        let mut active: HashSet<AgentId> = HashSet::new();
        for i in 0..n {
            slots.register(AgentId(i));
        }
        for _ in 0..200 {
            let window = rng.gen_range(1, n + 2) as usize;
            for a in slots.grant_up_to(window) {
                assert!(active.insert(a), "double-granted {a}");
            }
            // Random boundary events for active agents.
            let snapshot: Vec<AgentId> = active.iter().copied().collect();
            for a in snapshot {
                if released.contains(&a) {
                    continue;
                }
                match rng.gen_range(0, 4) {
                    0 => {
                        if slots.on_step_boundary(a, window) == BoundaryDecision::Paused
                        {
                            active.remove(&a);
                        }
                    }
                    1 => {
                        slots.release(a);
                        active.remove(&a);
                        released.insert(a);
                    }
                    _ => {}
                }
            }
            assert_eq!(slots.active_count(), active.len(), "seed {seed}");
            assert_eq!(
                slots.active_count() + slots.pending_count() + released.len(),
                n as usize,
                "seed {seed}: agents leaked"
            );
        }
    }
}

/// PROPERTY: JSON round-trips arbitrary generated values exactly.
#[test]
fn json_roundtrip_random_documents() {
    use concur::core::json::Value;
    use std::collections::BTreeMap;

    fn gen_value(rng: &mut Rng, depth: u32) -> Value {
        match if depth == 0 { rng.gen_range(0, 4) } else { rng.gen_range(0, 6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Number((rng.gen_range(0, 1 << 40) as f64) / 8.0),
            3 => Value::String(
                (0..rng.gen_range(0, 12))
                    .map(|_| {
                        char::from_u32(rng.gen_range(32, 1024) as u32).unwrap_or('x')
                    })
                    .collect(),
            ),
            4 => Value::Array(
                (0..rng.gen_range(0, 5))
                    .map(|_| gen_value(rng, depth - 1))
                    .collect(),
            ),
            _ => {
                let mut m = BTreeMap::new();
                for i in 0..rng.gen_range(0, 5) {
                    m.insert(format!("k{i}"), gen_value(rng, depth - 1));
                }
                Value::Object(m)
            }
        }
    }

    for seed in 0..200u64 {
        let mut rng = Rng::new(5000 + seed);
        let v = gen_value(&mut rng, 3);
        let compact = v.to_string_compact();
        let pretty = v.to_string_pretty();
        assert_eq!(Value::parse(&compact).unwrap(), v, "seed {seed}");
        assert_eq!(Value::parse(&pretty).unwrap(), v, "seed {seed}");
    }
}

/// PROPERTY (tentpole): the word-wise prefix comparator is exactly the
/// scalar `take_while` scan it replaced — over every length 0..=96 with a
/// divergence planted at every offset (covering each lane of the 4-token
/// word and every tail residue), and over randomized pairs up to 1024
/// tokens with unequal lengths and extreme token values.
#[test]
fn word_wise_comparator_equals_scalar_take_while() {
    use concur::core::simd::common_prefix_len;

    fn scalar(a: &[Token], b: &[Token]) -> usize {
        a.iter().zip(b).take_while(|(x, y)| x == y).count()
    }

    // Exhaustive small lengths: identical pair, then a divergence at
    // every offset.
    for len in 0..=96usize {
        let a: Vec<Token> =
            (0..len as u32).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
        assert_eq!(common_prefix_len(&a, &a), len, "identical len {len}");
        for off in 0..len {
            let mut b = a.clone();
            b[off] ^= 0x8000_0001;
            assert_eq!(common_prefix_len(&a, &b), off, "len {len} off {off}");
            assert_eq!(common_prefix_len(&a, &b), scalar(&a, &b), "len {len} off {off}");
        }
    }

    // Randomized lengths to 1024 (every alignment of the scalar tail),
    // shared prefix of random length, optional divergence inside it.
    let mut rng = Rng::new(0x51D_0001);
    for case in 0..2_000u32 {
        let la = rng.gen_range(0, 1025) as usize;
        let lb = rng.gen_range(0, 1025) as usize;
        let shared = la.min(lb);
        let a: Vec<Token> =
            (0..la).map(|_| rng.gen_range(0, 1 << 32) as u32).collect();
        let mut b: Vec<Token> = a[..shared].to_vec();
        b.extend((shared..lb).map(|_| rng.gen_range(0, 1 << 32) as u32));
        if shared > 0 && rng.chance(0.7) {
            let off = rng.gen_range(0, shared as u64) as usize;
            // Nonzero wrapping delta: guaranteed to actually diverge.
            b[off] = b[off].wrapping_add(1 + rng.gen_range(0, u32::MAX as u64) as u32);
        }
        assert_eq!(common_prefix_len(&a, &b), scalar(&a, &b), "case {case}");
        assert_eq!(common_prefix_len(&b, &a), scalar(&b, &a), "case {case} swapped");
    }
}

/// PROPERTY (tentpole): the epoch-memoized admission path is bit-identical
/// to re-matching the waiting queue's head every step.  Two engines run
/// the same randomized request stream in lockstep — one normal, one with
/// its memo cleared before every step (the pre-memo behaviour, via the
/// hidden oracle hook) — under pools small enough that admission
/// genuinely blocks, and every step's outcome, finished set, signals and
/// cumulative counters must match exactly.
#[test]
fn memoized_admission_equals_rematch_every_step() {
    use concur::config::{EngineConfig, EvictionMode};
    use concur::core::{AgentId, RequestId};
    use concur::costmodel::{ClusterSpec, CostModel, GpuSpec, ModelSpec};
    use concur::engine::{Request, SimEngine};

    for seed in 0..15u64 {
        let mut rng = Rng::new(9100 + seed);
        let pool = rng.gen_range(3_000, 30_000);
        let eviction = if rng.chance(0.5) {
            EvictionMode::Discard
        } else {
            EvictionMode::Offload
        };
        let mk = || {
            let cluster = ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), 4, 4);
            SimEngine::new(
                EngineConfig { eviction, hit_window: 8, ..EngineConfig::default() },
                CostModel::new(cluster),
            )
        };
        let mut memo = mk();
        let mut oracle = mk();
        memo.shrink_pool_for_tests(pool);
        oracle.shrink_pool_for_tests(pool);

        let mut rid = 0u64;
        let mut now = Micros::ZERO;
        for round in 0..4 {
            let n = rng.gen_range(2, 12) as usize;
            for _ in 0..n {
                let plen = rng.gen_range(16, 3_000);
                let glen = rng.gen_range(1, 100) as u32;
                // Family-shared prefixes so cached matches are non-trivial.
                let family = rng.gen_range(0, 3) as u32;
                let shared = rng.gen_range(0, plen.min(512)) as u32;
                let base = rng.gen_range(1 << 22, 1 << 24) as u32;
                let mut prompt: Vec<Token> =
                    (0..shared).map(|i| (1 << 28) + family * 4096 + i).collect();
                prompt.extend((0..plen as u32 - shared).map(|i| base + i));
                let gen: Vec<Token> =
                    (0..glen).map(|k| (1 << 26) + rid as u32 * 128 + k).collect();
                for engine in [&mut memo, &mut oracle] {
                    engine.submit(Request {
                        id: RequestId(rid),
                        agent: AgentId(rid % 5),
                        prompt: prompt.clone(),
                        gen: gen.clone(),
                        prev_ctx: 0,
                        submitted_at: now,
                    });
                }
                rid += 1;
            }
            for _ in 0..20_000 {
                assert_eq!(memo.has_work(), oracle.has_work(), "seed {seed}");
                if !memo.has_work() {
                    break;
                }
                oracle.clear_admit_memo();
                let a = memo.step(now);
                let b = oracle.step(now);
                let ctx = format!("seed {seed} round {round} t={now}");
                assert_eq!(a.duration, b.duration, "{ctx}: duration");
                assert_eq!(a.admitted, b.admitted, "{ctx}: admitted");
                assert_eq!(a.preempted, b.preempted, "{ctx}: preempted");
                assert_eq!(a.recompute_tokens, b.recompute_tokens, "{ctx}: recompute");
                assert_eq!(a.reload_time, b.reload_time, "{ctx}: reload");
                assert_eq!(a.finished.len(), b.finished.len(), "{ctx}: finished n");
                for (fa, fb) in a.finished.iter().zip(&b.finished) {
                    assert_eq!(fa.id, fb.id, "{ctx}: finished id");
                    assert_eq!(fa.agent, fb.agent, "{ctx}: finished agent");
                    assert_eq!(fa.output, fb.output, "{ctx}: finished output");
                    assert_eq!(fa.context_len, fb.context_len, "{ctx}: finished ctx");
                    assert_eq!(fa.admitted_at, fb.admitted_at, "{ctx}: admitted_at");
                }
                let (sa, sb) = (memo.signals(), oracle.signals());
                assert_eq!(sa.kv_usage.to_bits(), sb.kv_usage.to_bits(), "{ctx}: U");
                assert_eq!(sa.pool_usage.to_bits(), sb.pool_usage.to_bits(), "{ctx}: pool");
                assert_eq!(sa.hit_rate.to_bits(), sb.hit_rate.to_bits(), "{ctx}: H");
                assert_eq!(sa.running, sb.running, "{ctx}: running");
                assert_eq!(sa.waiting, sb.waiting, "{ctx}: waiting");
                now += a.duration + Micros(1);
                memo.check_invariants()
                    .unwrap_or_else(|e| panic!("{ctx}: memo engine: {e}"));
            }
            assert!(!memo.has_work(), "seed {seed}: engine stuck");
            assert_eq!(memo.counters, oracle.counters, "seed {seed}: counters");
            assert_eq!(memo.lifetime_hits.num, oracle.lifetime_hits.num, "seed {seed}");
            assert_eq!(memo.lifetime_hits.den, oracle.lifetime_hits.den, "seed {seed}");
        }
    }
}
