//! Workflow-DAG integration tests.
//!
//! The load-bearing properties: (1) execution — every DAG node runs
//! exactly once and no child ever finishes before a dependency; (2)
//! sharing — the planner-produced intermediate context lands
//! byte-identically (and chunk-aligned) in every consumer's prompt; (3)
//! replay — a fixed seed reproduces a workflow run bit-identically and
//! perturbing the workflow seed genuinely moves the schedule; (4) fault
//! cross — a kill landing mid fan-out loses no node and double-runs
//! none; (5) the acceptance claim — under pool pressure, a
//! lifetime-aware KV policy strictly beats plain LRU on aggregate hit
//! rate for at least one workflow shape.
//!
//! (That the workflow + lifetime machinery is invisible while disabled
//! is pinned by the differential oracle in `cluster_integration.rs`.)

mod common;

use common::assert_bit_identical;
use concur::agent::workflow_fleet;
use concur::config::{FaultEvent, FaultPlan, JobConfig, KvLifetimeMode, RouterKind};
use concur::core::Micros;
use concur::driver::{run_job, RunResult};
use concur::repro::run_systems;
use concur::repro::workflow::{base_job, POLICIES, SHAPES};

/// The repro-standard workflow cell scaled down to 4 DAGs — big enough
/// to exercise fan-out, fan-in and cross-graph interleaving, small
/// enough for tier-1.
fn small_job(shape: &'static str) -> JobConfig {
    base_job(KvLifetimeMode::Lru, shape, 4)
}

/// PROPERTY (execution): every DAG node executes exactly once, and
/// topological order is never violated — a consumer finishes strictly
/// after every dependency, for both shapes.
#[test]
fn every_dag_node_runs_exactly_once_in_topo_order() {
    for &(shape, _) in &SHAPES {
        let job = small_job(shape);
        let (agents, graph) = workflow_fleet(&job.workload);
        let r = run_job(&job).unwrap();
        assert_eq!(r.agents_finished, agents.len(), "{shape}: a node was lost");

        let mut ids: Vec<u64> = r.per_agent.iter().map(|o| o.agent.0).collect();
        ids.sort_unstable();
        assert_eq!(
            ids,
            (0..agents.len() as u64).collect::<Vec<u64>>(),
            "{shape}: every node must be recorded exactly once"
        );

        let mut fin = vec![Micros::ZERO; agents.len()];
        for o in &r.per_agent {
            fin[o.agent.0 as usize] = o.finished_at;
        }
        for a in &agents {
            for &c in graph.children_of(a.id) {
                assert!(
                    fin[c.0 as usize] > fin[a.id.0 as usize],
                    "{shape}: node {c} finished at {:?}, not after its \
                     dependency {} at {:?}",
                    fin[c.0 as usize],
                    a.id,
                    fin[a.id.0 as usize],
                );
            }
        }
    }
}

/// PROPERTY (sharing): the intermediate context the planner generates in
/// its first step is embedded byte-identically in every worker and
/// reducer prompt, at one chunk-aligned offset common to all consumers.
#[test]
fn consumers_embed_the_shared_context_byte_identically() {
    let job = small_job("mapreduce");
    let (agents, graph) = workflow_fleet(&job.workload);
    let s = job.workload.workflow.shared_context_tokens as usize;
    let w = job.workload.workflow.align_tokens as usize;
    let sys = job.workload.system_prompt_tokens as usize;
    let off = sys + (w - sys % w) % w;
    assert_eq!(off % w, 0, "shared context must start chunk-aligned");

    let mut consumers = 0;
    for planner in agents.iter().filter(|a| graph.is_ready(a.id)) {
        let gen0 = &planner.plan_for_stats()[0].gen;
        let shared = &gen0[gen0.len() - s..];
        for &c in graph.children_of(planner.id) {
            consumers += 1;
            assert_eq!(
                &agents[c.0 as usize].context()[off..off + s],
                shared,
                "worker {c} diverged from its planner's shared context"
            );
            for &rc in graph.children_of(c) {
                assert_eq!(
                    &agents[rc.0 as usize].context()[off..off + s],
                    shared,
                    "reducer {rc} diverged from its graph's shared context"
                );
            }
        }
    }
    assert!(consumers >= 8, "4 graphs at fanout 2-4 must produce >= 8 workers");
}

/// PROPERTY (replay): a workflow run replays bit-identically under a
/// fixed seed, and perturbing the workflow seed genuinely moves the
/// schedule — so the identity is not vacuous.
#[test]
fn fixed_seed_replays_bit_identically_and_perturbation_moves_it() {
    let job = small_job("mapreduce");
    let a = run_job(&job).unwrap();
    let b = run_job(&job).unwrap();
    assert_bit_identical(&a, &b, "workflow replay");

    let mut moved = job.clone();
    moved.workload.workflow.seed += 1;
    let c = run_job(&moved).unwrap();
    assert!(
        c.total_time != a.total_time || c.per_agent != a.per_agent,
        "perturbing the workflow seed must move the schedule"
    );
}

/// PROPERTY (fault cross): a kill landing mid fan-out — workers of
/// several graphs in flight, reducers still locked behind them — loses
/// no node, double-runs none, and the whole schedule stays deterministic.
#[test]
fn kill_mid_fanout_loses_no_node_and_double_runs_none() {
    let mut job = small_job("mapreduce");
    job.topology.replicas = 3;
    job.topology.router = RouterKind::Rebalance;
    let (agents, _) = workflow_fleet(&job.workload);

    // Anchor the kill at 40% of the healthy makespan: fan-outs from the
    // released planners are guaranteed mid-flight.
    let probe = run_job(&job).unwrap();
    job.topology.fault_plan =
        FaultPlan::new(vec![FaultEvent::kill(0, Micros(probe.total_time.0 * 2 / 5))]);

    let a = run_job(&job).unwrap();
    let b = run_job(&job).unwrap();
    assert_bit_identical(&a, &b, "workflow kill replay");
    assert_eq!(a.faults.kills, 1);
    assert_eq!(a.agents_finished, agents.len(), "the kill lost a node");
    let mut seen: Vec<u64> = a.per_agent.iter().map(|o| o.agent.0).collect();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), agents.len(), "a node outcome was double-counted");
}

/// ACCEPTANCE (tentpole, scaled down from `concur repro workflow`): on
/// the pressured cells of the policy grid — both workflow shapes at the
/// heavy fleet size against one TP2 pool — at least one lifetime-aware
/// KV policy (steps-to-execution or tool-ttl) strictly beats plain LRU
/// on aggregate hit rate.  Within a cell the fleets and release order
/// are identical across policies (pinned by the eviction-order oracle in
/// `proptests.rs` and the replay tests above), so any hit-rate gap is
/// the eviction policy's doing.
#[test]
fn a_lifetime_aware_policy_beats_lru_on_a_pressured_cell() {
    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for &(shape, _) in &SHAPES {
        for &policy in &POLICIES {
            labels.push((shape, policy));
            jobs.push(base_job(policy, shape, 16));
        }
    }
    let results = run_systems(jobs).unwrap();
    fn cell<'a>(
        labels: &[(&'static str, KvLifetimeMode)],
        results: &'a [RunResult],
        shape: &str,
        policy: KvLifetimeMode,
    ) -> &'a RunResult {
        let i = labels
            .iter()
            .position(|&(s, p)| s == shape && p == policy)
            .expect("complete grid");
        &results[i]
    }

    let mut wins = Vec::new();
    for &(shape, _) in &SHAPES {
        let fleet = workflow_fleet(&base_job(KvLifetimeMode::Lru, shape, 16).workload).0.len();
        let lru = cell(&labels, &results, shape, KvLifetimeMode::Lru);
        let steps = cell(&labels, &results, shape, KvLifetimeMode::StepsToExecution);
        let ttl = cell(&labels, &results, shape, KvLifetimeMode::ToolTtl);
        for (name, r) in [("lru", lru), ("steps-to-execution", steps), ("tool-ttl", ttl)] {
            assert_eq!(
                r.agents_finished, fleet,
                "{shape}/{name}: every policy must finish the whole fleet"
            );
        }
        // The cell genuinely thrashes: the claim is about eviction
        // *choice*, which needs evictions to choose between.
        assert!(lru.counters.evictions > 0, "{shape}/heavy must evict under lru");
        if steps.hit_rate > lru.hit_rate {
            wins.push(format!(
                "{shape}: steps-to-execution {:.4} > lru {:.4}",
                steps.hit_rate, lru.hit_rate
            ));
        }
        if ttl.hit_rate > lru.hit_rate {
            wins.push(format!(
                "{shape}: tool-ttl {:.4} > lru {:.4}",
                ttl.hit_rate, lru.hit_rate
            ));
        }
    }
    assert!(
        !wins.is_empty(),
        "no lifetime-aware policy beat lru on any pressured cell"
    );
}
