//! Open-loop traffic + stochastic fault integration tests.
//!
//! The load-bearing properties: (1) replay — with arrivals, abandonment,
//! shedding and MTBF/MTTR fault injection all enabled, a fixed seed
//! reproduces the run bit-identically, and perturbing the traffic seed
//! genuinely moves the schedule; (2) graceful degradation — an infinitely
//! patient open-loop fleet under sustained stochastic kills and drains
//! still finishes every session; (3) the acceptance claim — on an
//! overloaded fault-injected fleet, priority admission + shedding
//! strictly beats FIFO admission on high-priority goodput-under-SLO.
//!
//! (The closed-batch invisibility of all this machinery is pinned by the
//! differential oracle in `cluster_integration.rs`.)

mod common;

use common::{assert_bit_identical, small_cluster_job};
use concur::config::{FaultRateConfig, JobConfig, OpenLoopConfig, RouterKind};
use concur::driver::{run_job, RunResult};

/// The anchored 3-replica cell (see `common::small_cluster_job`) with
/// the open-loop arrival process and stochastic fault rates under test.
fn open_loop_job(n_agents: usize, ol: OpenLoopConfig, fr: FaultRateConfig) -> JobConfig {
    let mut job = small_cluster_job(n_agents, 3, RouterKind::CacheAffinity);
    job.topology.open_loop = ol;
    job.topology.fault_rates = fr;
    job
}

/// Every session is accounted for exactly once: served, shed at the
/// door, or abandoned while waiting.
fn assert_conservation(r: &RunResult, n: u64, ctx: &str) {
    assert_eq!(r.open_loop.arrived, n, "{ctx}: arrivals");
    assert_eq!(
        r.agents_finished as u64 + r.open_loop.shed + r.open_loop.abandoned,
        n,
        "{ctx}: served + shed + abandoned must cover every arrival"
    );
    assert_eq!(
        r.open_loop.finished_high + r.open_loop.finished_low,
        r.agents_finished as u64,
        "{ctx}: class split must cover every finish"
    );
    assert!(
        r.ttft.count() >= r.agents_finished as u64,
        "{ctx}: every finished session has a first-turn sample"
    );
}

/// PROPERTY (replay): with the full open-loop stack *and* stochastic
/// fault injection enabled, a fixed seed pair replays bit-identically —
/// and perturbing the traffic seed genuinely moves the schedule, so the
/// identity is not vacuous.
#[test]
fn open_loop_with_stochastic_faults_replays_bit_identically() {
    let ol = OpenLoopConfig { arrival_rate_per_s: 2.0, ..OpenLoopConfig::on() };
    let fr = FaultRateConfig { mtbf_s: 5.0, mttr_s: 2.0, ..FaultRateConfig::on() };
    let job = open_loop_job(24, ol, fr);
    let a = run_job(&job).unwrap();
    let b = run_job(&job).unwrap();
    assert_bit_identical(&a, &b, "replay");
    assert_conservation(&a, 24, "replay");
    assert!(
        a.faults.stochastic_injected + a.faults.stochastic_suppressed > 0,
        "the sampler must actually draw events at MTBF 5s"
    );

    // A different traffic seed is a different run.
    let mut moved = job.clone();
    moved.topology.open_loop.seed = 777;
    let c = run_job(&moved).unwrap();
    assert!(
        c.total_time != a.total_time || c.open_loop != a.open_loop,
        "perturbing the traffic seed must move the schedule"
    );
}

/// PROPERTY (graceful degradation): an infinitely patient open-loop
/// fleet with shedding off, under sustained stochastic kills and drains,
/// still serves every single session — faults may slow the fleet down
/// but never lose work.
#[test]
fn patient_open_loop_fleet_survives_sustained_faults_without_losing_sessions() {
    let ol = OpenLoopConfig {
        arrival_rate_per_s: 2.0,
        patience_s: 0.0, // infinitely patient
        shed: false,
        priority_admission: false,
        ..OpenLoopConfig::on()
    };
    let fr =
        FaultRateConfig { mtbf_s: 4.0, mttr_s: 2.0, drain_share: 0.5, ..FaultRateConfig::on() };
    let r = run_job(&open_loop_job(24, ol, fr)).unwrap();
    assert_eq!(r.agents_finished, 24, "no session may be lost");
    assert_eq!(r.open_loop.shed, 0);
    assert_eq!(r.open_loop.abandoned, 0);
    assert_conservation(&r, 24, "patient fleet");
    assert!(
        r.faults.stochastic_injected > 0,
        "the run must actually have been fault-injected (mtbf 4s)"
    );
}

/// ACCEPTANCE (tentpole): on an overloaded, fault-injected open-loop
/// fleet — 64 sessions arriving at 4/s into an AIMD-controlled 3-replica
/// cluster with MTBF 60s — priority admission plus hysteretic shedding
/// strictly beats plain FIFO admission on **high-priority
/// goodput-under-SLO**: shedding not-yet-started low-priority sessions
/// under backlog frees capacity for the high class, and priority
/// admission stops high sessions from queueing (and abandoning) behind
/// low ones.
#[test]
fn priority_shedding_beats_fifo_on_high_priority_goodput_under_slo() {
    let shaped = |priority: bool| OpenLoopConfig {
        arrival_rate_per_s: 4.0,
        patience_s: 45.0,
        slo_ttft_s: 30.0,
        slo_step_s: 60.0,
        priority_admission: priority,
        shed: priority,
        ..OpenLoopConfig::on()
    };
    let fr =
        FaultRateConfig { mtbf_s: 60.0, mttr_s: 15.0, drain_share: 0.5, ..FaultRateConfig::on() };

    let concur = run_job(&open_loop_job(64, shaped(true), fr)).unwrap();
    let fifo = run_job(&open_loop_job(64, shaped(false), fr)).unwrap();
    assert_conservation(&concur, 64, "priority+shed");
    assert_conservation(&fifo, 64, "fifo");

    // The scenario is genuinely overloaded: FIFO loses sessions to
    // abandonment, the governor trips and sheds in the priority arm.
    assert!(fifo.open_loop.abandoned > 0, "FIFO arm must be overloaded");
    assert!(concur.open_loop.shed > 0, "governor must shed under backlog");
    assert_eq!(fifo.open_loop.shed, 0, "nothing is shed with shedding off");

    assert!(
        concur.open_loop.goodput_high > fifo.open_loop.goodput_high,
        "high-priority goodput-under-SLO: priority+shed {} must strictly beat FIFO {}",
        concur.open_loop.goodput_high,
        fifo.open_loop.goodput_high
    );
}
