//! Engine-level integration: the SGLang-like substrate under multi-agent
//! workload patterns, checked against its own accounting invariants.

use concur::config::{EngineConfig, EvictionMode};
use concur::core::{AgentId, Micros, RequestId, Token};
use concur::costmodel::{ClusterSpec, CostModel, GpuSpec, ModelSpec};
use concur::engine::{Request, SimEngine};

fn engine(pool_tokens: u64, eviction: EvictionMode) -> SimEngine {
    let cluster = ClusterSpec::new(GpuSpec::h100(), ModelSpec::qwen3_32b(), 8, 8);
    let cfg = EngineConfig { eviction, hit_window: 8, ..EngineConfig::default() };
    let mut e = SimEngine::new(cfg, CostModel::new(cluster));
    e.shrink_pool_for_tests(pool_tokens);
    e
}

fn req(id: u64, agent: u64, prompt: Vec<Token>, gen: u32, prev_ctx: u64) -> Request {
    Request {
        id: RequestId(id),
        agent: AgentId(agent),
        prompt,
        gen: (0..gen).map(|k| 0x3000_0000 + id as u32 * 4096 + k).collect(),
        prev_ctx,
        submitted_at: Micros::ZERO,
    }
}

fn drain(e: &mut SimEngine, cap: usize) -> Vec<concur::engine::FinishedReq> {
    let mut now = Micros::ZERO;
    let mut out = Vec::new();
    for _ in 0..cap {
        if !e.has_work() {
            break;
        }
        let step = e.step(now);
        now += step.duration + Micros(1);
        out.extend(step.finished);
        e.check_invariants().unwrap();
    }
    assert!(!e.has_work(), "engine failed to drain in {cap} steps");
    out
}

#[test]
fn sixteen_agents_multi_step_with_shared_prefix() {
    let mut e = engine(400_000, EvictionMode::Discard);
    let sys: Vec<Token> = (0..512).collect();
    let mut histories: Vec<Vec<Token>> = (0..16)
        .map(|a| {
            let mut p = sys.clone();
            p.extend((0..600).map(|i| 0x0100_0000 + a as u32 * 65536 + i));
            p
        })
        .collect();

    let mut rid = 0u64;
    let mut prev_ctx = vec![0u64; 16];
    for step in 0..4 {
        for a in 0..16usize {
            let r = req(rid, a as u64, histories[a].clone(), 40, prev_ctx[a]);
            rid += 1;
            e.submit(r);
        }
        let done = drain(&mut e, 10_000);
        assert_eq!(done.len(), 16);
        for f in done {
            let a = f.agent.0 as usize;
            histories[a].extend(f.output);
            // Recompute boundary: everything the model has computed so far
            // (prompt + generation), NOT the upcoming tool observation.
            prev_ctx[a] = histories[a].len() as u64;
            histories[a].extend(
                (0..150).map(|i| 0x0200_0000 + a as u32 * 65536 + step as u32 * 256 + i),
            );
        }
    }
    // Ample pool: the shared system prompt and each agent's own history
    // are fully reused; recompute never happens.
    assert_eq!(e.counters.recompute_tokens, 0);
    assert!(e.lifetime_hits.ratio() > 0.5, "hit={}", e.lifetime_hits.ratio());
}

#[test]
fn thrash_regime_shows_recompute_and_preserves_invariants() {
    // Pool fits ~4 of 12 growing agents: heavy eviction, but accounting
    // must stay exact through every step.
    let mut e = engine(12_000, EvictionMode::Discard);
    let mut histories: Vec<Vec<Token>> = (0..12)
        .map(|a| ((a as u32 * 0x0010_0000)..(a as u32 * 0x0010_0000) + 800).collect())
        .collect();
    let mut rid = 0;
    let mut prev_ctx = vec![0u64; 12];
    for step in 0..3 {
        for a in 0..12usize {
            e.submit(req(rid, a as u64, histories[a].clone(), 30, prev_ctx[a]));
            rid += 1;
        }
        let done = drain(&mut e, 200_000);
        for f in done {
            let a = f.agent.0 as usize;
            histories[a].extend(f.output);
            prev_ctx[a] = histories[a].len() as u64;
            histories[a]
                .extend((0..200).map(|i| 0x0300_0000 + rid as u32 * 512 + a as u32 + i * 7));
        }
    }
    assert!(e.counters.evicted_tokens > 0);
    assert!(e.counters.recompute_tokens > 0);
    assert!(e.lifetime_hits.ratio() < 0.9);
}

#[test]
fn offload_mode_preserves_invariants_under_pressure() {
    let mut e = engine(10_000, EvictionMode::Offload);
    let mut rid = 0;
    for wave in 0..3 {
        for a in 0..8usize {
            let base = 0x0400_0000 + a as u32 * 0x0008_0000 + wave as u32 * 97;
            e.submit(req(rid, a as u64, (base..base + 2_000).collect(), 25, 0));
            rid += 1;
        }
        drain(&mut e, 50_000);
    }
    assert!(e.counters.offloaded_tokens > 0);
    assert!(e.tree().cpu_tokens() > 0 || e.counters.reloaded_tokens > 0);
}

#[test]
fn preemption_restores_exact_accounting() {
    // Tiny pool forces decode to preempt prefilling victims repeatedly.
    let mut e = engine(6_000, EvictionMode::Discard);
    for a in 0..6u64 {
        let base = 0x0500_0000 + a as u32 * 0x0010_0000;
        e.submit(req(a, a, (base..base + 1_800).collect(), 60, 0));
    }
    let done = drain(&mut e, 100_000);
    assert_eq!(done.len(), 6);
    assert!(e.counters.preemptions > 0, "expected preemption churn");
    e.check_invariants().unwrap();
}

#[test]
fn hit_rate_window_reflects_recent_traffic_only() {
    let mut e = engine(100_000, EvictionMode::Discard);
    // First wave: all misses.
    for a in 0..8u64 {
        let base = 0x0600_0000 + a as u32 * 0x0010_0000;
        e.submit(req(a, a, (base..base + 1_000).collect(), 10, 0));
    }
    drain(&mut e, 10_000);
    let early = e.hit_rate();
    // Second wave: identical prompts -> pure hits.
    for a in 0..8u64 {
        let base = 0x0600_0000 + a as u32 * 0x0010_0000;
        e.submit(req(100 + a, a, (base..base + 1_000).collect(), 10, 1_010));
    }
    drain(&mut e, 10_000);
    assert!(e.hit_rate() > early);
    assert!(e.hit_rate() > 0.9, "window hit={}", e.hit_rate());
}
