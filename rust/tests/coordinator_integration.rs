//! Coordinator-level integration: the AIMD controller + slot manager
//! driving a live engine, exercising the paper's control-law claims.

use concur::config::AimdParams;
use concur::coordinator::{AimdController, ControlInputs, Controller, SlotManager};
use concur::core::AgentId;
use concur::engine::EngineSignals;

fn inputs(u: f64, h: f64, active: usize) -> ControlInputs {
    ControlInputs {
        engine: EngineSignals {
            kv_usage: u,
            pool_usage: u,
            hit_rate: h,
            running: active,
            waiting: 0,
        },
        active_agents: active,
        active_footprint: (u * 1_000_000.0) as u64,
        capacity: 1_000_000,
    }
}

#[test]
fn full_congestion_episode() {
    // warmup growth → saturation hold → hit collapse → single cut →
    // drain → recovery hold — the paper's Figure 5 arc in miniature.
    let p = AimdParams {
        control_interval: 1,
        cut_cooldown: 4,
        band_probe_every: 0,
        ..AimdParams::default()
    };
    let mut c = AimdController::new(p);

    // Warmup: underutilized & saturated → grows.
    for _ in 0..10 {
        let w = c.window();
        c.on_signals(&inputs(0.1, 0.95, w));
    }
    let peak = c.window_f();
    assert!(peak > p.w_init);

    // Saturation with healthy hit rate → holds.
    for _ in 0..10 {
        let w = c.window();
        c.on_signals(&inputs(0.9, 0.8, w));
    }
    assert_eq!(c.window_f(), peak);

    // Hit collapse at saturation → exactly one cut (β), then gated while
    // the active population is still above the window.
    let over = c.window() + 10;
    for _ in 0..5 {
        c.on_signals(&inputs(0.95, 0.05, over));
    }
    assert_eq!(c.window_f(), peak); // not drained yet → no cut
    c.on_signals(&inputs(0.95, 0.05, c.window()));
    assert_eq!(c.window_f(), peak * 0.5);
    assert_eq!(c.cuts, 1);
}

#[test]
fn slots_and_controller_cooperate_on_window_shrink() {
    let mut slots = SlotManager::new();
    for i in 0..10 {
        slots.register(AgentId(i));
    }
    let granted = slots.grant_up_to(10);
    assert_eq!(granted.len(), 10);

    // Window shrinks to 4: the next six step-boundaries pause.
    let mut paused = 0;
    for i in 0..10 {
        if slots.on_step_boundary(AgentId(i), 4)
            == concur::coordinator::slots::BoundaryDecision::Paused
        {
            paused += 1;
        }
    }
    assert_eq!(paused, 6);
    assert_eq!(slots.active_count(), 4);

    // Window recovers to 7: exactly three resume, LIFO.
    let resumed = slots.grant_up_to(7);
    assert_eq!(resumed.len(), 3);
    assert_eq!(slots.active_count(), 7);
    assert_eq!(slots.resumes, 3);
}

#[test]
fn aimd_window_bounded_under_adversarial_signals() {
    // Whatever the signal sequence, the window stays within [w_min, w_max].
    let p = AimdParams {
        control_interval: 1,
        cut_cooldown: 0,
        w_min: 2.0,
        w_init: 4.0,
        w_max: 64.0,
        ..AimdParams::default()
    };
    let mut c = AimdController::new(p);
    let mut rng = concur::core::Rng::new(99);
    for _ in 0..5_000 {
        let u = rng.next_f64() * 1.5; // can exceed 1.0 (footprint > pool)
        let h = rng.next_f64();
        let w = c.window();
        let active = (rng.next_u64() % 128) as usize;
        c.on_signals(&inputs(u, h, if rng.chance(0.5) { w } else { active }));
        let wf = c.window_f();
        assert!((2.0..=64.0).contains(&wf), "window escaped: {wf}");
    }
}

#[test]
fn window_history_is_recorded_for_fig5() {
    let p = AimdParams { control_interval: 2, ..AimdParams::default() };
    let mut c = AimdController::new(p);
    for _ in 0..20 {
        let w = c.window();
        c.on_signals(&inputs(0.1, 0.9, w));
    }
    // 20 signals / interval 2 = 10 control decisions recorded.
    assert_eq!(c.window_history().len(), 10);
}
