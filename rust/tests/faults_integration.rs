//! Fault-layer integration tests: replica kill, drain-and-refill,
//! cold-first rebalancing and per-replica tool skew.
//!
//! Fault instants are always anchored to a healthy probe run of the same
//! job: the healthy and faulted runs are event-identical up to the fault
//! instant, and the healthy run still has unfinished agents at any
//! fraction of its makespan — so an anchored fault is *guaranteed* to
//! fire mid-run, for any seed.

use concur::config::presets;
use concur::config::{
    AimdParams, EngineConfig, FaultEvent, FaultPlan, JobConfig, PrefixTierConfig,
    RouterKind, SchedulerKind, TopologyConfig, WorkloadConfig,
};
use concur::core::Micros;
use concur::driver::{run_job, RunResult};

fn fleet_job(replicas: usize, router: RouterKind, n_agents: usize) -> JobConfig {
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: WorkloadConfig {
            n_agents,
            steps_min: 4,
            steps_max: 6,
            ..WorkloadConfig::default()
        },
        // No admission control by default: every agent is active, so a
        // mid-run fault always has in-flight work to disrupt.
        scheduler: SchedulerKind::Uncontrolled,
        topology: TopologyConfig { replicas, router, ..TopologyConfig::default() },
    }
}

fn frac(t: Micros, f: f64) -> Micros {
    Micros((t.0 as f64 * f) as u64)
}

/// Sorted (id, generated tokens) — the finished-set fingerprint.
fn finished_set(r: &RunResult) -> Vec<(u64, u64)> {
    let mut v: Vec<(u64, u64)> =
        r.per_agent.iter().map(|o| (o.agent.0, o.gen_tokens)).collect();
    v.sort_unstable();
    v
}

/// A mid-run replica kill never loses agents: every router finishes the
/// full fleet, dead-replica work re-enters the admission queue, and the
/// admissible-replica series records the loss.
#[test]
fn kill_mid_run_preserves_completion_under_every_router() {
    let mut total_requeued = 0;
    for router in [
        RouterKind::RoundRobin,
        RouterKind::LeastLoaded,
        RouterKind::CacheAffinity,
        RouterKind::Rebalance,
    ] {
        let base = fleet_job(3, router, 24);
        let healthy = run_job(&base).unwrap();
        let mut job = base.clone();
        job.topology.fault_plan =
            FaultPlan::new(vec![FaultEvent::kill(0, frac(healthy.total_time, 0.5))]);
        let r = run_job(&job).unwrap();
        assert_eq!(r.agents_finished, 24, "{router:?} lost agents after the kill");
        assert_eq!(r.faults.kills, 1, "{router:?}");
        assert_eq!(finished_set(&r), finished_set(&healthy), "{router:?} finished set");
        assert_eq!(r.alive_series.points().last().unwrap().1, 2.0, "{router:?}");
        total_requeued += r.faults.requeued_agents;
    }
    // Across four mid-run kills of a fully-active fleet, at least one
    // agent must have had a step in flight on the dying replica.
    assert!(total_requeued > 0, "no agent was ever requeued by a mid-run kill");
}

/// Kill + revive runs are deterministic end to end: identical totals,
/// counters, fault telemetry and per-agent records across repeats.
#[test]
fn kill_and_revive_runs_are_deterministic() {
    let base = fleet_job(3, RouterKind::Rebalance, 24);
    let healthy = run_job(&base).unwrap();
    // Kill at 35% of the healthy makespan, revive at 55%: the faulted
    // run is event-identical to healthy until the kill, and the healthy
    // fleet still has ~65% of its makespan of work left there — on two
    // surviving replicas that cannot be done by 55%, so the revive is
    // guaranteed to fire mid-run.
    let mut job = base.clone();
    job.topology.fault_plan = FaultPlan::new(vec![
        FaultEvent::kill(1, frac(healthy.total_time, 0.35)),
        FaultEvent::revive(1, frac(healthy.total_time, 0.55)),
    ]);
    let a = run_job(&job).unwrap();
    let b = run_job(&job).unwrap();
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.counters, b.counters);
    assert_eq!(a.hit_rate.to_bits(), b.hit_rate.to_bits());
    assert_eq!(a.engine_steps, b.engine_steps);
    assert_eq!(a.faults, b.faults);
    assert_eq!(a.per_agent, b.per_agent);
    assert_eq!(a.faults.kills, 1);
    assert_eq!(a.faults.revives, 1);
    // After the revive the full fleet is admissible again.
    assert_eq!(a.alive_series.points().last().unwrap().1, 3.0);
    assert_eq!(a.agents_finished, 24);
}

/// PROPERTY (satellite): drain-then-refill with no concurrent faults
/// finishes the same set of agents (by id and generated-output length)
/// as an undisturbed run at the same seed — drains disturb placement and
/// timing, never completion.  Checked across seeds and two routers.
#[test]
fn drain_then_refill_preserves_finished_set_across_seeds() {
    for &seed in &[1u64, 7, 23, 101, 555] {
        for router in [RouterKind::CacheAffinity, RouterKind::Rebalance] {
            let mut base = fleet_job(3, router, 18);
            base.workload.seed = seed;
            let healthy = run_job(&base).unwrap();
            let mut job = base.clone();
            job.topology.fault_plan =
                FaultPlan::new(vec![FaultEvent::drain(0, frac(healthy.total_time, 0.4))]);
            let drained = run_job(&job).unwrap();
            assert_eq!(
                finished_set(&healthy),
                finished_set(&drained),
                "seed {seed} {router:?}: drain changed the finished set"
            );
            assert_eq!(drained.faults.drains, 1, "seed {seed} {router:?}");
            assert_eq!(
                drained.faults.refills, 1,
                "seed {seed} {router:?}: drained replica never refilled"
            );
            assert_eq!(drained.faults.requeued_agents, 0, "drain must not requeue");
            // Back to a fully admissible fleet after the refill.
            assert_eq!(drained.alive_series.points().last().unwrap().1, 3.0);
        }
    }
}

/// ACCEPTANCE: under a mid-run replica kill, the cold-first rebalancing
/// router out-delivers pure least-loaded balancing on throughput — the
/// point of migrating cold agents first is that the surviving replicas
/// keep their warm working sets.
#[test]
fn rebalance_beats_least_loaded_under_mid_run_kill() {
    // Paper-shaped scenario scaled down for tier-1: CONCUR admission,
    // 4 replicas, fixed offered load, one replica dies mid-run.
    let job_for = |router: RouterKind| JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: WorkloadConfig {
            n_agents: 32,
            steps_min: 5,
            steps_max: 7,
            ..WorkloadConfig::default()
        },
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig { replicas: 4, router, ..TopologyConfig::default() },
    };
    // One shared anchor so both routers face the identical kill.
    let anchor = run_job(&job_for(RouterKind::LeastLoaded)).unwrap().total_time;
    let kill = FaultPlan::new(vec![FaultEvent::kill(0, frac(anchor, 0.45))]);

    let mut ll = job_for(RouterKind::LeastLoaded);
    ll.topology.fault_plan = kill.clone();
    let mut rb = job_for(RouterKind::Rebalance);
    rb.topology.fault_plan = kill;

    let ll = run_job(&ll).unwrap();
    let rb = run_job(&rb).unwrap();
    assert_eq!(ll.agents_finished, 32);
    assert_eq!(rb.agents_finished, 32);
    assert!(
        rb.throughput_tps > ll.throughput_tps,
        "rebalance {:.0} tok/s did not beat least-loaded {:.0} tok/s under a kill",
        rb.throughput_tps,
        ll.throughput_tps
    );
    assert!(
        rb.hit_rate > ll.hit_rate,
        "rebalance hit rate {:.3} did not beat least-loaded {:.3}",
        rb.hit_rate,
        ll.hit_rate
    );
}

/// `fleet_job` with the shared-prefix broadcast tier switched on (and a
/// family count coprime with the replica count, so every family's prefix
/// genuinely splits across replicas and the tier has work to do).
fn tier_fleet_job(replicas: usize, router: RouterKind, n_agents: usize) -> JobConfig {
    let mut job = fleet_job(replicas, router, n_agents);
    job.workload.task_families = 5;
    job.topology.prefix_tier = PrefixTierConfig::on();
    job
}

/// Fault × tier (satellite): killing a replica destroys its broadcast
/// pins with its radix tree; on revive, the tier must re-ship the hot
/// prefixes to the rejoining replica — and the fleet still finishes.
#[test]
fn kill_then_revive_reships_the_broadcast_tier() {
    let base = tier_fleet_job(3, RouterKind::CacheAffinity, 24);
    let healthy = run_job(&base).unwrap();
    assert!(healthy.prefix_tier.ships > 0, "tier idle in the healthy probe");
    assert_eq!(healthy.prefix_tier.reships, 0, "healthy fleets never re-ship");

    let mut job = base.clone();
    job.topology.fault_plan = FaultPlan::new(vec![
        FaultEvent::kill(0, frac(healthy.total_time, 0.35)),
        FaultEvent::revive(0, frac(healthy.total_time, 0.55)),
    ]);
    let r = run_job(&job).unwrap();
    assert_eq!(r.agents_finished, 24);
    assert_eq!(r.faults.kills, 1);
    assert_eq!(r.faults.revives, 1);
    assert!(
        r.prefix_tier.reships > 0,
        "revived replica must get the broadcast tier re-shipped"
    );
    assert_eq!(finished_set(&r), finished_set(&healthy));
}

/// Fault × tier (satellite): a drained replica wipes its cache at the
/// refill, so it rejoins with the tier re-shipped; continuity holds (no
/// requeues, same finished set as the undisturbed tier-on run).
#[test]
fn drain_and_refill_rejoins_with_the_tier_restored() {
    let base = tier_fleet_job(3, RouterKind::Rebalance, 18);
    let healthy = run_job(&base).unwrap();
    assert!(healthy.prefix_tier.ships > 0);

    let mut job = base.clone();
    job.topology.fault_plan =
        FaultPlan::new(vec![FaultEvent::drain(0, frac(healthy.total_time, 0.4))]);
    let r = run_job(&job).unwrap();
    assert_eq!(r.faults.drains, 1);
    assert_eq!(r.faults.refills, 1, "drained replica never refilled");
    assert_eq!(r.faults.requeued_agents, 0, "drain must not requeue");
    assert!(
        r.prefix_tier.reships > 0,
        "refilled replica must get the broadcast tier re-shipped"
    );
    assert_eq!(finished_set(&r), finished_set(&healthy));
}

/// Fault × tier (satellite): kill + revive with the tier on is
/// deterministic end to end — totals, counters, fault *and* tier
/// telemetry replay bit-identically.
#[test]
fn kill_and_revive_with_tier_on_is_deterministic() {
    let base = tier_fleet_job(3, RouterKind::Rebalance, 24);
    let healthy = run_job(&base).unwrap();
    let mut job = base.clone();
    job.topology.fault_plan = FaultPlan::new(vec![
        FaultEvent::kill(1, frac(healthy.total_time, 0.35)),
        FaultEvent::revive(1, frac(healthy.total_time, 0.55)),
    ]);
    let a = run_job(&job).unwrap();
    let b = run_job(&job).unwrap();
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.counters, b.counters);
    assert_eq!(a.hit_rate.to_bits(), b.hit_rate.to_bits());
    assert_eq!(a.engine_steps, b.engine_steps);
    assert_eq!(a.faults, b.faults);
    assert_eq!(a.per_agent, b.per_agent);
    assert_eq!(a.prefix_tier, b.prefix_tier, "tier telemetry must replay");
    assert_eq!(a.broadcast_series.len(), b.broadcast_series.len());
    for (pa, pb) in a.broadcast_series.points().iter().zip(b.broadcast_series.points()) {
        assert_eq!(pa.0, pb.0);
        assert_eq!(pa.1.to_bits(), pb.1.to_bits());
    }
    assert_eq!(a.agents_finished, 24);
}

/// Per-replica tool skew: agents homed on the slow-tool replica finish
/// strictly later than in the unskewed fleet (their tool waits are on
/// their own critical path), other cohorts are broadly unaffected, and
/// skewed runs stay deterministic.
#[test]
fn tool_skew_slows_the_skewed_cohort_deterministically() {
    let base = fleet_job(3, RouterKind::CacheAffinity, 24);
    let even = run_job(&base).unwrap();
    let mut skewed = base.clone();
    skewed.topology.tool_skew = vec![1.0, 1.0, 4.0];
    let a = run_job(&skewed).unwrap();
    let b = run_job(&skewed).unwrap();
    assert_eq!(a.total_time, b.total_time, "skewed runs must be deterministic");
    assert_eq!(a.counters, b.counters);
    assert_eq!(a.agents_finished, 24);
    assert_eq!(finished_set(&a), finished_set(&even));

    let finish_of = |r: &RunResult| {
        let mut m = vec![Micros::ZERO; 24];
        for o in &r.per_agent {
            m[o.agent.0 as usize] = o.finished_at;
        }
        m
    };
    let (fe, fs) = (finish_of(&even), finish_of(&a));
    // Cache-affinity homes are id % replicas: ids = 2 (mod 3) live on the
    // 4x-skewed replica 2.  Every one of them finishes strictly later.
    for id in (2..24).step_by(3) {
        assert!(
            fs[id] > fe[id],
            "agent {id} on the skewed replica finished at {} vs {} unskewed",
            fs[id],
            fe[id]
        );
    }
    // The fleet as a whole can only get slower.
    assert!(a.total_time >= even.total_time);
}
