//! Asynchronous-transport integration tests.
//!
//! The load-bearing properties: (1) conservation — every fabric byte a
//! transfer claims is a byte the shared link actually carried; (2)
//! causality — nothing a transfer ships is matchable, hittable or
//! routing-visible before its `done` instant; (3) determinism — full
//! cluster runs with the tier, delayed visibility, delta shipping and
//! drain handoff all enabled are bit-identical across repeats, for
//! several seeds; and (4) the acceptance claim — on one anchored
//! drained workload, KV handoff yields a strictly higher post-drain
//! aggregate hit rate than drop-on-drain.

mod common;

use common::{assert_bit_identical, small_cluster_job};
use concur::cluster::{SharedPrefixTier, Transport};
use concur::config::{
    presets, EngineConfig, FaultEvent, FaultPlan, JobConfig, PrefixTierConfig, RouterKind,
    SchedulerKind, TopologyConfig, TransportConfig,
};
use concur::core::{AgentId, Micros, RequestId, Token};
use concur::costmodel::CostModel;
use concur::driver::{run_job, RunResult};
use concur::engine::{Request, SimEngine};

fn engines(n: usize) -> Vec<SimEngine> {
    (0..n)
        .map(|_| {
            let mut e = SimEngine::new(
                EngineConfig::default(),
                CostModel::new(presets::qwen3_cluster(2)),
            );
            e.shrink_pool_for_tests(100_000);
            e
        })
        .collect()
}

fn family_prompt(agent: u32) -> Vec<Token> {
    let mut p: Vec<Token> = (0..512).collect();
    p.extend(1_000_000 + agent * 10_000..1_000_000 + agent * 10_000 + 400);
    p
}

/// Drive one request to completion so the prompt lands in the replica's
/// radix cache through the normal finish path.
fn serve(e: &mut SimEngine, id: u64, agent: u64, prompt: Vec<Token>) {
    e.submit(Request {
        id: RequestId(id),
        agent: AgentId(agent),
        prompt,
        gen: vec![90_000_000 + id as Token],
        prev_ctx: 0,
        submitted_at: Micros::ZERO,
    });
    let mut now = Micros::ZERO;
    for _ in 0..300 {
        if !e.has_work() {
            break;
        }
        let out = e.step(now);
        now = now + out.duration + Micros(1);
    }
    assert!(!e.has_work(), "request did not finish");
}

/// PROPERTY (causality + accounting): with delayed visibility on, a
/// request admitted while the install's transfer is in flight accrues
/// **zero** broadcast hit tokens; the first request after the commit
/// hits the full prefix.  Fabric bytes are conserved throughout.
#[test]
fn no_broadcast_hits_accrue_before_the_install_lands() {
    let mut eng = engines(2);
    let mut tier = SharedPrefixTier::new(PrefixTierConfig::on(), 2);
    let mut cfg = TransportConfig::on();
    cfg.delayed_visibility = true;
    let mut tp = Transport::new(cfg, eng[0].cost.cluster.model.kv_bytes_per_token());
    let alive = vec![true, true];

    // Three distinct agents make the family prefix hot; replica 0 serves
    // one of them and becomes the broadcast source.
    for a in 0..3u32 {
        tier.observe(AgentId(a as u64), &family_prompt(a), Micros(a as u64 + 1));
    }
    serve(&mut eng[0], 900, 900, family_prompt(9));
    tier.maintain(&mut eng, &alive, Micros(10), Some(&mut tp));
    let done = tp.next_completion().expect("peer install must be in flight");
    assert!(done > Micros(10), "completion lands strictly after issue");

    // A family request served by replica 1 BEFORE the transfer lands:
    // the pending prefix matches zero tokens — no broadcast hits, full
    // re-prefill, exactly as if the tier had not shipped yet.
    serve(&mut eng[1], 901, 50, family_prompt(50));
    assert_eq!(eng[1].counters.broadcast_hit_tokens, 0, "no hits before done");

    // The transfer lands; the commit pins whatever the early request
    // did not already re-create, and from now on requests hit it.
    for xfer in tp.pop_due(done) {
        tier.on_transfer_done(&xfer, &mut eng, done);
    }
    assert_eq!(eng[1].tree().broadcast_tokens(), 512);
    serve(&mut eng[1], 902, 51, family_prompt(51));
    assert_eq!(eng[1].counters.broadcast_hit_tokens, 512, "post-commit requests hit");

    // Conservation: claimed wire bytes == bytes the fabric carried.
    assert_eq!(tp.stats().wire_bytes, tp.fabric_bytes_moved());
    for e in &eng {
        e.check_invariants().unwrap();
    }
}

/// The anchored 3-replica cell (see `common::small_cluster_job`) with
/// the tier on and the transport under test.
fn transport_job(seed: u64, transport: TransportConfig) -> JobConfig {
    let mut job = small_cluster_job(24, 3, RouterKind::Rebalance);
    job.workload.seed = seed;
    job.topology.prefix_tier = PrefixTierConfig::on();
    job.topology.transport = transport;
    job
}

/// PROPERTY (determinism): the full stack — tier + delayed visibility +
/// delta shipping + drain handoff under a mid-run drain — reproduces
/// bit-identically across repeats, for 5 seeds.  Transfer completion
/// instants are part of the event clock, so any nondeterminism in their
/// scheduling or delivery order would surface here.
#[test]
fn delayed_transport_runs_are_deterministic_across_seeds() {
    for seed in [11u64, 22, 33, 44, 55] {
        let mut cfg = TransportConfig::on();
        cfg.delayed_visibility = true;
        cfg.delta_ship = true;
        cfg.drain_handoff = true;
        let mut job = transport_job(seed, cfg);
        // Anchor a drain mid-run off a healthy probe of the same cell.
        let probe = run_job(&job).unwrap();
        job.topology.fault_plan =
            FaultPlan::new(vec![FaultEvent::drain(0, Micros(probe.total_time.0 * 2 / 5))]);
        let a = run_job(&job).unwrap();
        let b = run_job(&job).unwrap();
        assert_bit_identical(&a, &b, &format!("seed {seed}"));
        assert_eq!(a.agents_finished, 24, "seed {seed} must finish");
        assert_eq!(a.faults.drains, 1);
        // The full stack genuinely engaged: transfers flowed.
        assert!(a.transport.transfers > 0, "seed {seed}: no transfers flowed");
    }
}

/// PROPERTY (double fault): a kill landing on a replica *mid
/// drain-handoff* — its checkpoints still crossing the fabric — cancels
/// the in-flight transfers cleanly: no agent is lost, no agent outcome
/// is recorded twice, and the whole schedule is deterministic across
/// seeds.  The fabric is deliberately slowed to 1 Gbps so the handoffs
/// issued at the drain instant are guaranteed still in flight when the
/// kill lands 2 ms later.
#[test]
fn kill_mid_drain_handoff_cancels_transfers_without_losing_agents() {
    for seed in [11u64, 22, 33, 44, 55] {
        let mut cfg = TransportConfig::on();
        cfg.delayed_visibility = true;
        cfg.drain_handoff = true;
        cfg.fabric_gbps = 1.0;
        let mut job = transport_job(seed, cfg);
        let probe = run_job(&job).unwrap();
        let drain_at = Micros(probe.total_time.0 * 2 / 5);
        job.topology.fault_plan = FaultPlan::new(vec![
            FaultEvent::drain(0, drain_at),
            FaultEvent::kill(0, drain_at + Micros(2_000)),
        ]);
        let a = run_job(&job).unwrap();
        let b = run_job(&job).unwrap();
        assert_bit_identical(&a, &b, &format!("double fault seed {seed}"));

        // The race genuinely engaged: the drain checkpointed agents and
        // the kill voided checkpoints still on the wire.
        assert!(a.faults.handoff_agents > 0, "seed {seed}: drain must checkpoint");
        assert!(a.transport.cancelled > 0, "seed {seed}: kill must cancel in-flight");
        assert_eq!(a.faults.drains, 1, "seed {seed}");
        assert_eq!(a.faults.kills, 1, "seed {seed}");

        // No agent lost, none double-counted: every agent finishes and
        // is recorded exactly once.
        assert_eq!(a.agents_finished, 24, "seed {seed}: agents lost");
        let mut seen: Vec<u64> = a.per_agent.iter().map(|o| o.agent.0).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 24, "seed {seed}: an agent outcome was double-counted");
    }
}

/// Every transport corner completes the fleet (smoke across the cube).
#[test]
fn every_transport_mode_completes_under_a_drain() {
    for &(delayed, delta, handoff) in &[
        (false, false, true),
        (false, true, false),
        (true, false, false),
        (true, true, true),
    ] {
        let cfg = TransportConfig {
            enabled: true,
            delayed_visibility: delayed,
            delta_ship: delta,
            drain_handoff: handoff,
            ..TransportConfig::default()
        };
        let mut job = transport_job(7, cfg);
        job.topology.fault_plan =
            FaultPlan::new(vec![FaultEvent::drain(1, Micros(40_000_000))]);
        let r = run_job(&job).unwrap();
        assert_eq!(
            r.agents_finished, 24,
            "mode delayed={delayed} delta={delta} handoff={handoff} lost agents"
        );
    }
}

/// ACCEPTANCE (tentpole): on one anchored workload with a mid-run drain
/// of replica 0, KV handoff yields a strictly higher post-drain
/// aggregate hit rate than drop-on-drain.  N=2 so every displaced agent
/// (and its handed-off context) must land on replica 1 — the benefit is
/// causal, not a routing accident — and the router is `rebalance`, whose
/// stored pins keep the handed-off agents on the replica their KV was
/// shipped to (a stateless rehash would walk them back to the refilled,
/// cold replica).  The pool (TP4) comfortably fits the displaced working
/// set, so the shipped contexts survive to be hit.
#[test]
fn drain_handoff_beats_drop_on_post_drain_hit_rate() {
    let base = |transport: TransportConfig| JobConfig {
        cluster: presets::qwen3_cluster(4),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: presets::qwen3_workload(32),
        // No admission control: isolates the handoff's cache effect.
        scheduler: SchedulerKind::Uncontrolled,
        topology: TopologyConfig {
            replicas: 2,
            router: RouterKind::Rebalance,
            transport,
            ..TopologyConfig::default()
        },
    };
    let drop_cfg = TransportConfig::on();
    let mut hand_cfg = TransportConfig::on();
    hand_cfg.drain_handoff = true;

    // Anchor the drain at 40% of the healthy makespan: both runs are
    // identical up to that instant, so the drain is guaranteed mid-run
    // and the pre-drain history is shared.
    let healthy = run_job(&base(drop_cfg)).unwrap();
    let drain_at = Micros((healthy.total_time.0 as f64 * 0.4) as u64);
    let plan = FaultPlan::new(vec![FaultEvent::drain(0, drain_at)]);

    let mut drop_job = base(drop_cfg);
    drop_job.topology.fault_plan = plan.clone();
    let mut hand_job = base(hand_cfg);
    hand_job.topology.fault_plan = plan;

    let dropped = run_job(&drop_job).unwrap();
    let handed = run_job(&hand_job).unwrap();
    assert_eq!(dropped.agents_finished, 32);
    assert_eq!(handed.agents_finished, 32);
    assert_eq!(dropped.faults.refills, 1, "the drain must refill");
    assert_eq!(dropped.faults.handoff_agents, 0);
    assert!(handed.faults.handoff_agents > 0, "warm agents must be checkpointed");
    assert!(handed.faults.handoff_tokens > 0);
    assert!(handed.counters.handoff_installed_tokens > 0, "contexts must land");

    let window_end = |r: &RunResult| r.total_time + Micros(1);
    let post_drop = dropped.hit_series.mean_in(drain_at, window_end(&dropped));
    let post_hand = handed.hit_series.mean_in(drain_at, window_end(&handed));
    assert!(
        post_hand > post_drop,
        "post-drain aggregate hit rate: handoff {post_hand:.4} must strictly beat \
         drop-on-drain {post_drop:.4}"
    );
}
