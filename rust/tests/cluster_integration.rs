//! Cluster-layer integration tests.
//!
//! The load-bearing property: the N=1 cluster path must reproduce the
//! pre-refactor single-engine driver **bit-identically** — same latency,
//! counters, series points and controller trajectory — so the multi-layer
//! refactor cannot silently change any paper result.  The pre-refactor
//! `run_with` loop is embedded verbatim below as a behavioral oracle
//! (driving the engine through its public API), in the same differential
//! style PR 1 used for the radix-tree rewrite.
//!
//! On top of that: N=4 determinism for every router, and the routing
//! claim itself — cache-affinity ≥ load-balancing policies on lifetime
//! hit rate once there is more than one replica to be wrong about.

use concur::config::presets;
use concur::config::{
    AimdParams, EngineConfig, EvictionMode, FaultPlan, FaultRateConfig, JobConfig,
    OpenLoopConfig, PrefixTierConfig, RouterKind, SchedulerKind, TopologyConfig,
    TransportConfig, WorkloadConfig,
};
use concur::core::Rng;
use concur::driver::{run_job, RunResult};
use concur::metrics::ALL_PHASES;

/// Pre-refactor driver, embedded verbatim as the behavioral oracle (only
/// the `crate::` paths and the RunResult's new replica/fault fields
/// adapted — a single-engine run has no faults and one always-admissible
/// replica).
mod reference {
    use concur::agent::Agent;
    use concur::cluster::{FaultStats, OpenLoopStats, PrefixTierStats, TransportStats};
    use concur::coordinator::slots::BoundaryDecision;
    use concur::coordinator::{ControlInputs, Controller, SlotManager};
    use concur::core::{AgentId, Micros, RequestId};
    use concur::driver::{AgentOutcome, RunResult};
    use concur::engine::SimEngine;
    use concur::metrics::{Histogram, Phase, TimeSeries};
    use concur::sim::{EventQueue, SimClock};

    pub fn run_with(
        engine: &mut SimEngine,
        agents: Vec<Agent>,
        mut controller: Box<dyn Controller>,
    ) -> RunResult {
        if let Some(cap) = controller.engine_request_cap() {
            engine.cfg.max_running = cap;
        }

        let mut slots = SlotManager::new();
        let total_gen: u64 = agents.iter().map(|a| a.total_gen_tokens()).sum();
        let agents_total = agents.len();
        let mut fleet: Vec<Agent> = agents;
        fleet.sort_by_key(|a| a.id.0);
        for (i, a) in fleet.iter().enumerate() {
            assert_eq!(a.id.0 as usize, i, "driver requires dense agent ids");
            slots.register(a.id);
        }
        fn agent(fleet: &mut [Agent], id: AgentId) -> &mut Agent {
            &mut fleet[id.0 as usize]
        }
        let mut active_footprint: u64 = 0;

        let mut clock = SimClock::new();
        let mut events: EventQueue<AgentId> = EventQueue::new();
        let mut next_req: u64 = 0;
        let mut result_breakdown_toolwait = Micros::ZERO;

        let mut usage_series = TimeSeries::new("kv_usage");
        let mut hit_series = TimeSeries::new("hit_rate");
        let mut active_series = TimeSeries::new("active_agents");
        let mut window_series = TimeSeries::new("window");
        let mut agent_latency = Histogram::new("agent_e2e_latency");
        let mut alive_series = TimeSeries::new("admissible_replicas");
        alive_series.record(Micros::ZERO, 1.0);
        let mut per_agent: Vec<AgentOutcome> = Vec::with_capacity(agents_total);

        let mut finished_agents = 0usize;
        let mut engine_steps = 0u64;

        loop {
            let now = clock.now();

            // 1. Deliver due tool completions; paused agents wait.
            while let Some((_, aid)) = events.pop_due(now) {
                let a = agent(&mut fleet, aid);
                a.on_tool_done();
                if slots.on_step_boundary(aid, controller.window())
                    == BoundaryDecision::Continue
                {
                    let req = a.make_request(RequestId(next_req), now);
                    next_req += 1;
                    engine.submit(req);
                } else {
                    active_footprint -= a.context_len() as u64; // paused
                }
            }

            // 2. Grant freed slots (resume paused LIFO, admit fresh FIFO).
            for aid in slots.grant_up_to(controller.window()) {
                let a = agent(&mut fleet, aid);
                active_footprint += a.context_len() as u64;
                let req = a.make_request(RequestId(next_req), now);
                next_req += 1;
                engine.submit(req);
            }

            // 3. Advance: engine iteration, or jump to the next event.
            if engine.has_work() {
                let out = engine.step(now);
                engine_steps += 1;
                clock.advance(Micros(out.duration.0.max(1)));
                let after = clock.now();

                for fin in out.finished {
                    let a = agent(&mut fleet, fin.agent);
                    let before = a.context_len() as u64;
                    match a.on_step_finished(&fin.output, after) {
                        Some(tool_latency) => {
                            active_footprint += a.context_len() as u64 - before;
                            events.push(after + tool_latency, fin.agent);
                        }
                        None => {
                            active_footprint -= before; // slot released
                            slots.release(fin.agent);
                            finished_agents += 1;
                            let start = a.started_at.unwrap_or(Micros::ZERO);
                            agent_latency.record(after.saturating_sub(start));
                            per_agent.push(AgentOutcome {
                                agent: fin.agent,
                                gen_tokens: a.total_gen_tokens(),
                                finished_at: after,
                            });
                        }
                    }
                }

                let sig = engine.signals();
                controller.on_signals(&ControlInputs {
                    engine: sig,
                    active_agents: slots.active_count(),
                    active_footprint,
                    capacity: engine.pool().capacity(),
                });
                usage_series.record(after, sig.pool_usage);
                hit_series.record(after, sig.hit_rate);
                active_series.record(after, slots.active_count() as f64);
                let w = controller.window();
                window_series.record(after, if w == usize::MAX { f64::NAN } else { w as f64 });
            } else if let Some(t) = events.peek_time() {
                result_breakdown_toolwait += t.saturating_sub(now);
                clock.advance_to(t);
            } else {
                break; // no engine work, no future events → done
            }
        }

        assert_eq!(finished_agents, agents_total, "reference run incomplete");

        let total_time = clock.now();
        let mut breakdown = std::mem::take(&mut engine.breakdown);
        breakdown.add(Phase::ToolWait, result_breakdown_toolwait);
        let throughput_tps = if total_time.0 > 0 {
            total_gen as f64 / total_time.as_secs_f64()
        } else {
            0.0
        };

        RunResult {
            scheduler: controller.name(),
            total_time,
            breakdown,
            hit_rate: engine.lifetime_hits.ratio(),
            counters: engine.counters,
            usage_series,
            hit_series,
            active_series,
            window_series,
            agents_total,
            agents_finished: finished_agents,
            total_gen_tokens: total_gen,
            throughput_tps,
            agent_latency,
            engine_steps,
            pauses: slots.pauses,
            resumes: slots.resumes,
            replicas: 1,
            router: "single".into(),
            faults: FaultStats::default(),
            alive_series,
            per_agent,
            prefix_tier: PrefixTierStats::default(),
            broadcast_series: TimeSeries::new("broadcast_shipped_tokens"),
            transport: TransportStats::default(),
            ttft: Histogram::new("ttft"),
            step_latency: Histogram::new("step_latency"),
            open_loop: OpenLoopStats::default(),
        }
    }
}

/// Run `job` through the embedded pre-refactor driver.
fn reference_run(job: &JobConfig) -> RunResult {
    use concur::agent::WorkloadGenerator;
    use concur::coordinator::make_controller;
    use concur::costmodel::CostModel;
    use concur::engine::SimEngine;

    job.validate().unwrap();
    let agents = WorkloadGenerator::new(job.workload.clone()).generate();
    let controller = make_controller(&job.scheduler);
    let mut engine = SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone()));
    reference::run_with(&mut engine, agents, controller)
}

/// Bitwise comparison of everything a RunResult records (NaN-tolerant for
/// the window series: unbounded windows record NaN points).
fn assert_bit_identical(a: &RunResult, b: &RunResult, ctx: &str) {
    assert_eq!(a.scheduler, b.scheduler, "{ctx}: scheduler");
    assert_eq!(a.total_time, b.total_time, "{ctx}: total_time");
    assert_eq!(a.counters, b.counters, "{ctx}: counters");
    assert_eq!(a.hit_rate.to_bits(), b.hit_rate.to_bits(), "{ctx}: hit_rate");
    assert_eq!(a.throughput_tps.to_bits(), b.throughput_tps.to_bits(), "{ctx}: throughput");
    assert_eq!(a.engine_steps, b.engine_steps, "{ctx}: engine_steps");
    assert_eq!(a.agents_finished, b.agents_finished, "{ctx}: agents_finished");
    assert_eq!(a.total_gen_tokens, b.total_gen_tokens, "{ctx}: gen tokens");
    assert_eq!(a.pauses, b.pauses, "{ctx}: pauses");
    assert_eq!(a.resumes, b.resumes, "{ctx}: resumes");
    for p in ALL_PHASES {
        assert_eq!(a.breakdown.get(p), b.breakdown.get(p), "{ctx}: breakdown {}", p.name());
    }
    assert_eq!(a.faults, b.faults, "{ctx}: fault stats");
    assert_eq!(a.prefix_tier, b.prefix_tier, "{ctx}: prefix-tier stats");
    assert_eq!(a.transport, b.transport, "{ctx}: transport stats");
    assert_eq!(a.per_agent, b.per_agent, "{ctx}: per-agent records");
    for (name, sa, sb) in [
        ("usage", &a.usage_series, &b.usage_series),
        ("hit", &a.hit_series, &b.hit_series),
        ("active", &a.active_series, &b.active_series),
        ("window", &a.window_series, &b.window_series),
        ("alive", &a.alive_series, &b.alive_series),
        ("broadcast", &a.broadcast_series, &b.broadcast_series),
    ] {
        assert_eq!(sa.len(), sb.len(), "{ctx}: {name} series length");
        for (pa, pb) in sa.points().iter().zip(sb.points()) {
            assert_eq!(pa.0, pb.0, "{ctx}: {name} series timestamp");
            assert_eq!(pa.1.to_bits(), pb.1.to_bits(), "{ctx}: {name} series value");
        }
    }
    assert_eq!(a.agent_latency.count(), b.agent_latency.count(), "{ctx}: latency n");
    assert_eq!(a.agent_latency.mean(), b.agent_latency.mean(), "{ctx}: latency mean");
    assert_eq!(a.agent_latency.max(), b.agent_latency.max(), "{ctx}: latency max");
    assert_eq!(a.open_loop, b.open_loop, "{ctx}: open-loop stats");
    for (name, ha, hb) in [("ttft", &a.ttft, &b.ttft), ("step", &a.step_latency, &b.step_latency)] {
        assert_eq!(ha.count(), hb.count(), "{ctx}: {name} n");
        assert_eq!(ha.mean(), hb.mean(), "{ctx}: {name} mean");
        assert_eq!(ha.max(), hb.max(), "{ctx}: {name} max");
    }
}

/// Seeded random small jobs across schedulers and eviction modes (same
/// recipe as the parallel-sweep proptest).
fn random_jobs(n: usize) -> Vec<JobConfig> {
    let mut rng = Rng::new(0xD1FF);
    (0..n)
        .map(|i| {
            let scheduler = match i % 4 {
                0 => SchedulerKind::Uncontrolled,
                1 => SchedulerKind::Concur(AimdParams::default()),
                2 => SchedulerKind::AgentCap(rng.gen_range(2, 6) as usize),
                _ => SchedulerKind::RequestCap(rng.gen_range(2, 6) as usize),
            };
            let eviction = if rng.chance(0.5) {
                EvictionMode::Discard
            } else {
                EvictionMode::Offload
            };
            JobConfig {
                cluster: presets::qwen3_cluster(8),
                engine: EngineConfig {
                    eviction,
                    hit_window: 8,
                    ..EngineConfig::default()
                },
                workload: WorkloadConfig {
                    n_agents: rng.gen_range(4, 12) as usize,
                    steps_min: 2,
                    steps_max: 4,
                    seed: rng.gen_range(1, 1_000),
                    ..WorkloadConfig::default()
                },
                scheduler,
                topology: TopologyConfig::default(),
            }
        })
        .collect()
}

/// PROPERTY (differential): the N=1 cluster path is bit-identical to the
/// pre-refactor single-engine driver on random jobs, whichever router the
/// topology names (routing must short-circuit at one replica), and an
/// explicit `FaultPlan::none()` with identity tool skew changes nothing —
/// the fault/skew machinery must be invisible until configured.
#[test]
fn n1_cluster_matches_prerefactor_driver_bitwise() {
    for (i, base) in random_jobs(8).iter().enumerate() {
        let want = reference_run(base);
        for router in [
            RouterKind::RoundRobin,
            RouterKind::LeastLoaded,
            RouterKind::CacheAffinity,
            RouterKind::Rebalance,
        ] {
            let mut job = base.clone();
            job.topology = TopologyConfig { replicas: 1, router, ..TopologyConfig::default() };
            let got = run_job(&job).unwrap();
            assert_bit_identical(&got, &want, &format!("job {i} via {router:?}"));
        }
        // Explicit no-fault plan + identity skew: still the oracle.
        let mut job = base.clone();
        job.topology = TopologyConfig {
            replicas: 1,
            router: RouterKind::CacheAffinity,
            fault_plan: FaultPlan::none(),
            tool_skew: vec![1.0],
            prefix_tier: PrefixTierConfig::default(),
            transport: TransportConfig::default(),
            open_loop: OpenLoopConfig::default(),
            fault_rates: FaultRateConfig::default(),
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with explicit no-fault topology"));
        // An explicitly *disabled* prefix tier — whatever its other knobs
        // say — must also be the oracle: the enable flag gates everything.
        let mut job = base.clone();
        job.topology.prefix_tier = PrefixTierConfig {
            enabled: false,
            hot_after: 2,
            budget_tokens: 1_000_000,
            min_prefix_tokens: 1,
            ..PrefixTierConfig::default()
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with disabled prefix tier"));
        // A disabled transport with its dormant knobs changed must also
        // be the oracle: the legacy teleport path is untouched.
        let mut job = base.clone();
        job.topology.transport = TransportConfig {
            enabled: false,
            fabric_gbps: 1.0,
            handoff_budget_tokens: 3,
            handoff_max_agents: 1,
            ..TransportConfig::default()
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with disabled transport"));
        // Disabled open-loop traffic + disabled stochastic faults, dormant
        // knobs cranked: the closed-batch path must not notice them.
        let mut job = base.clone();
        job.topology.open_loop = OpenLoopConfig {
            enabled: false,
            arrival_rate_per_s: 50.0,
            diurnal_amplitude: 1.0,
            patience_s: 0.001,
            high_priority_share: 0.9,
            shed_on_ratio: 0.1,
            shed_off_ratio: 0.05,
            ..OpenLoopConfig::default()
        };
        job.topology.fault_rates = FaultRateConfig {
            enabled: false,
            mtbf_s: 0.001,
            mttr_s: 0.001,
            drain_share: 1.0,
            ..FaultRateConfig::default()
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with disabled open-loop"));
    }
}

fn routing_job(replicas: usize, router: RouterKind) -> JobConfig {
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: WorkloadConfig {
            n_agents: 32,
            steps_min: 5,
            steps_max: 7,
            ..WorkloadConfig::default()
        },
        // No admission control: isolates pure routing effects (no pauses).
        scheduler: SchedulerKind::Uncontrolled,
        topology: TopologyConfig { replicas, router, ..TopologyConfig::default() },
    }
}

/// PROPERTY: cache-affinity routing at N=4 yields identical results
/// across repeated runs — the cluster loop has no hidden nondeterminism
/// (map iteration order, time ties, router state).
#[test]
fn n4_cluster_runs_are_deterministic() {
    for router in [
        RouterKind::RoundRobin,
        RouterKind::LeastLoaded,
        RouterKind::CacheAffinity,
        RouterKind::Rebalance,
    ] {
        let job = routing_job(4, router);
        let a = run_job(&job).unwrap();
        let b = run_job(&job).unwrap();
        assert_bit_identical(&a, &b, &format!("repeat {router:?} N=4"));
        assert_eq!(a.replicas, 4);
    }
}

/// PROPERTY (differential, tier satellite): with the tier disabled — the
/// default — `run_sharded` output at N=4 is bit-identical to the
/// pre-tier cluster, whatever the disabled tier's other knobs say.  Any
/// tier bookkeeping that leaks into the disabled path (an observe, a
/// maintenance pass, a routing hint) breaks this immediately.
#[test]
fn n4_tier_off_machinery_is_invisible() {
    for router in [RouterKind::CacheAffinity, RouterKind::Rebalance, RouterKind::LeastLoaded] {
        let plain = routing_job(4, router);
        let want = run_job(&plain).unwrap();
        let mut weird = plain.clone();
        weird.topology.prefix_tier = PrefixTierConfig {
            enabled: false,
            hot_after: 2,
            budget_tokens: 999_999,
            min_prefix_tokens: 1,
            ..PrefixTierConfig::default()
        };
        let got = run_job(&weird).unwrap();
        assert_bit_identical(&got, &want, &format!("{router:?} N=4 disabled tier"));
        assert_eq!(got.prefix_tier, Default::default(), "disabled tier must report zeros");
        assert!(got.broadcast_series.is_empty());
    }
}

/// PROPERTY (differential, transport satellite): with `TransportConfig`
/// at defaults — instantaneous visibility, full-ship, drop-on-drain —
/// `run_sharded` output at N=4 is bit-identical to the pre-transport
/// cluster, dormant knobs notwithstanding.  Any transport bookkeeping
/// leaking into the disabled path (a fabric charge, a completion clock
/// stop, a handoff) breaks this immediately.
#[test]
fn n4_transport_off_machinery_is_invisible() {
    for router in [RouterKind::CacheAffinity, RouterKind::Rebalance] {
        let plain = routing_job(4, router);
        let want = run_job(&plain).unwrap();
        let mut dormant = plain.clone();
        dormant.topology.transport = TransportConfig {
            enabled: false,
            fabric_gbps: 0.001,
            handoff_budget_tokens: 1,
            handoff_max_agents: 1,
            ..TransportConfig::default()
        };
        let got = run_job(&dormant).unwrap();
        assert_bit_identical(&got, &want, &format!("{router:?} N=4 disabled transport"));
        assert_eq!(got.transport, Default::default(), "disabled transport must report zeros");
    }
}

/// PROPERTY (differential, open-loop tentpole): with `OpenLoopConfig` and
/// `FaultRateConfig` disabled — the defaults — `run_sharded` output at
/// N=4 is bit-identical to the closed-batch cluster, however the dormant
/// knobs are set.  Any open-loop bookkeeping leaking into the closed path
/// (an arrival clock stop, a latency sample, a governor observation, a
/// sampler draw) breaks this immediately.
#[test]
fn n4_open_loop_off_machinery_is_invisible() {
    for router in [RouterKind::CacheAffinity, RouterKind::Rebalance] {
        let plain = routing_job(4, router);
        let want = run_job(&plain).unwrap();
        let mut dormant = plain.clone();
        dormant.topology.open_loop = OpenLoopConfig {
            enabled: false,
            arrival_rate_per_s: 100.0,
            patience_s: 0.001,
            slo_ttft_s: 0.001,
            slo_step_s: 0.001,
            priority_admission: true,
            shed: true,
            ..OpenLoopConfig::default()
        };
        dormant.topology.fault_rates = FaultRateConfig {
            enabled: false,
            mtbf_s: 0.01,
            mttr_s: 0.01,
            ..FaultRateConfig::default()
        };
        let got = run_job(&dormant).unwrap();
        assert_bit_identical(&got, &want, &format!("{router:?} N=4 disabled open-loop"));
        assert_eq!(got.open_loop, Default::default(), "disabled open-loop must report zeros");
        assert_eq!(got.ttft.count(), 0, "no TTFT samples in a closed-batch run");
        assert_eq!(got.step_latency.count(), 0, "no step-latency samples in a closed-batch run");
    }
}

/// ACCEPTANCE (tier): in the thrashing regime — where LRU pressure
/// repeatedly evicts and re-prefills whole family subtrees — the
/// broadcast tier's pins keep the shared prefixes resident on every
/// replica, recovering cross-agent hits the tier-off fleet structurally
/// loses.  N=4 with 5 task families (coprime: every family splits across
/// all replicas) at paper-depth trajectories, a scaled-down cell of the
/// `prefix_sharing` sweep the nightly bench runs at N∈{1,2,4,8}.
#[test]
fn tier_on_recovers_shared_prefix_hits_under_thrashing() {
    let mut off = routing_job(4, RouterKind::CacheAffinity);
    // Paper-depth contexts: ~16 agents/replica at ~22k final tokens
    // overflow the TP2 pool (~253k slots), so the run genuinely thrashes.
    off.workload = presets::qwen3_workload(64);
    off.workload.task_families = 5;
    off.scheduler = SchedulerKind::Concur(AimdParams::default());
    let mut on = off.clone();
    on.topology.prefix_tier = PrefixTierConfig::on();

    let off = run_job(&off).unwrap();
    let on = run_job(&on).unwrap();
    assert_eq!(off.agents_finished, 64);
    assert_eq!(on.agents_finished, 64);
    assert!(off.counters.evicted_tokens > 0, "scenario must actually thrash");
    assert!(on.prefix_tier.hot_prefixes > 0, "family prefixes must go hot");
    assert!(on.prefix_tier.ships > 0, "hot prefixes must ship");
    assert!(on.counters.broadcast_hit_tokens > 0, "shipped prefixes must be hit");
    assert!(
        on.hit_rate > off.hit_rate,
        "tier on {:.4} must beat tier off {:.4} on lifetime hit rate at N=4",
        on.hit_rate,
        off.hit_rate
    );
    assert_eq!(off.prefix_tier, Default::default());
}

/// The routing claim itself: once agents have warm prefixes to lose,
/// pinning them (cache-affinity) beats per-request load balancing on
/// lifetime hit rate — least-loaded migrates an agent whenever another
/// replica dips below its current one, round-robin migrates every step.
#[test]
fn cache_affinity_beats_balancers_on_hit_rate_at_n4() {
    let aff = run_job(&routing_job(4, RouterKind::CacheAffinity)).unwrap();
    let ll = run_job(&routing_job(4, RouterKind::LeastLoaded)).unwrap();
    let rr = run_job(&routing_job(4, RouterKind::RoundRobin)).unwrap();
    assert!(
        aff.hit_rate >= ll.hit_rate,
        "affinity {:.3} < least-loaded {:.3}",
        aff.hit_rate,
        ll.hit_rate
    );
    assert!(
        aff.hit_rate >= rr.hit_rate,
        "affinity {:.3} < round-robin {:.3}",
        aff.hit_rate,
        rr.hit_rate
    );
    // All routers finish the full fleet either way.
    for r in [&aff, &ll, &rr] {
        assert_eq!(r.agents_finished, 32);
    }
}

/// Sharding sanity: with dense agent ids, cache-affinity at N=4 keeps the
/// per-agent trajectory hit rate close to the single-replica driver (the
/// whole point of pinning) while the balancers pay real misses.
#[test]
fn affinity_preserves_single_replica_hit_rate() {
    let single = run_job(&routing_job(1, RouterKind::CacheAffinity)).unwrap();
    let aff = run_job(&routing_job(4, RouterKind::CacheAffinity)).unwrap();
    // Pinned agents extend the same radix path on their home replica, so
    // sharding may only change hit rate through cross-agent sharing of
    // the system prompt (task families now split across replicas).
    assert!(
        aff.hit_rate > single.hit_rate * 0.9,
        "affinity N=4 {:.3} lost more than 10% of single-replica {:.3}",
        aff.hit_rate,
        single.hit_rate
    );
}

/// PROPERTY (tentpole acceptance): `run_sharded` output is bit-identical
/// across step-worker counts {1, 2, 4} on the **full-stack** config —
/// broadcast tier + asynchronous transport + open-loop traffic +
/// stochastic fault injection all enabled at once — over 5 seeds.  The
/// parallel event-clock merge only changes how ready replicas are
/// stepped between clock stops, never what any of the machinery above
/// observes.  (The CI determinism job pins the same claim end-to-end via
/// `CONCUR_WORKERS` on `concur repro cluster`.)
#[test]
fn full_stack_run_is_bit_identical_across_step_worker_counts() {
    use concur::agent::open_loop_fleet;
    use concur::cluster::{make_router, run_sharded_with_workers};
    use concur::coordinator::make_controller;
    use concur::costmodel::CostModel;
    use concur::engine::SimEngine;

    for seed in 0..5u64 {
        let job = JobConfig {
            cluster: presets::qwen3_cluster(2),
            engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
            workload: WorkloadConfig {
                n_agents: 16,
                steps_min: 3,
                steps_max: 5,
                task_families: 5,
                seed: 40 + seed,
                ..WorkloadConfig::default()
            },
            scheduler: SchedulerKind::Concur(AimdParams::default()),
            topology: TopologyConfig {
                replicas: 3,
                router: RouterKind::CacheAffinity,
                prefix_tier: PrefixTierConfig::on(),
                transport: TransportConfig::on(),
                open_loop: OpenLoopConfig {
                    arrival_rate_per_s: 2.0,
                    seed: 100 + seed,
                    ..OpenLoopConfig::on()
                },
                fault_rates: FaultRateConfig {
                    mtbf_s: 5.0,
                    mttr_s: 2.0,
                    ..FaultRateConfig::on()
                },
                ..TopologyConfig::default()
            },
        };
        job.validate().unwrap();

        let run_at = |step_workers: usize| -> RunResult {
            let n = job.topology.replicas;
            let mut engines: Vec<SimEngine> = (0..n)
                .map(|_| SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone())))
                .collect();
            let mut router = make_router(job.topology.router);
            let agents = open_loop_fleet(&job.workload, &job.topology.open_loop);
            run_sharded_with_workers(
                &mut engines,
                router.as_mut(),
                agents,
                make_controller(&job.scheduler),
                &job.topology.fault_plan,
                &job.topology.tool_skew,
                &job.topology.prefix_tier,
                &job.topology.transport,
                &job.topology.open_loop,
                &job.topology.fault_rates,
                step_workers,
            )
            .unwrap()
        };

        let sequential = run_at(1);
        // The run must actually exercise the machinery it claims to pin.
        assert!(sequential.open_loop.arrived > 0, "seed {seed}: no open-loop arrivals");
        assert!(
            sequential.faults.stochastic_injected + sequential.faults.stochastic_suppressed > 0,
            "seed {seed}: the fault sampler never drew"
        );
        for workers in [2usize, 4] {
            let parallel = run_at(workers);
            assert_bit_identical(
                &parallel,
                &sequential,
                &format!("seed {seed}, {workers} step workers vs sequential"),
            );
        }
    }
}
