//! Cluster-layer integration tests.
//!
//! The load-bearing property: the N=1 cluster path must reproduce the
//! pre-refactor single-engine driver **bit-identically** — same latency,
//! counters, series points and controller trajectory — so the multi-layer
//! refactor cannot silently change any paper result.  The pre-refactor
//! `run_with` loop is embedded verbatim below as a behavioral oracle
//! (driving the engine through its public API), in the same differential
//! style PR 1 used for the radix-tree rewrite.
//!
//! On top of that: N=4 determinism for every router, and the routing
//! claim itself — cache-affinity ≥ load-balancing policies on lifetime
//! hit rate once there is more than one replica to be wrong about.
//!
//! The oracle runner, the exhaustive `RunResult` comparison and the
//! anchored job builders live in `tests/common/mod.rs`, shared with the
//! transport / open-loop / workflow suites.

mod common;

use common::{assert_bit_identical, random_jobs, reference_run};
use concur::config::presets;
use concur::config::{
    AimdParams, EngineConfig, FaultPlan, FaultRateConfig, JobConfig, KvLifetimeMode,
    OpenLoopConfig, PrefixTierConfig, RouterKind, SchedulerKind, TopologyConfig,
    TransportConfig, WorkflowConfig, WorkloadConfig,
};
use concur::driver::{run_job, RunResult};

/// PROPERTY (differential): the N=1 cluster path is bit-identical to the
/// pre-refactor single-engine driver on random jobs, whichever router the
/// topology names (routing must short-circuit at one replica), and an
/// explicit `FaultPlan::none()` with identity tool skew changes nothing —
/// the fault/skew machinery must be invisible until configured.
#[test]
fn n1_cluster_matches_prerefactor_driver_bitwise() {
    for (i, base) in random_jobs(8).iter().enumerate() {
        let want = reference_run(base);
        for router in [
            RouterKind::RoundRobin,
            RouterKind::LeastLoaded,
            RouterKind::CacheAffinity,
            RouterKind::Rebalance,
        ] {
            let mut job = base.clone();
            job.topology = TopologyConfig { replicas: 1, router, ..TopologyConfig::default() };
            let got = run_job(&job).unwrap();
            assert_bit_identical(&got, &want, &format!("job {i} via {router:?}"));
        }
        // Explicit no-fault plan + identity skew: still the oracle.
        let mut job = base.clone();
        job.topology = TopologyConfig {
            replicas: 1,
            router: RouterKind::CacheAffinity,
            fault_plan: FaultPlan::none(),
            tool_skew: vec![1.0],
            prefix_tier: PrefixTierConfig::default(),
            transport: TransportConfig::default(),
            open_loop: OpenLoopConfig::default(),
            fault_rates: FaultRateConfig::default(),
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with explicit no-fault topology"));
        // An explicitly *disabled* prefix tier — whatever its other knobs
        // say — must also be the oracle: the enable flag gates everything.
        let mut job = base.clone();
        job.topology.prefix_tier = PrefixTierConfig {
            enabled: false,
            hot_after: 2,
            budget_tokens: 1_000_000,
            min_prefix_tokens: 1,
            ..PrefixTierConfig::default()
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with disabled prefix tier"));
        // A disabled transport with its dormant knobs changed must also
        // be the oracle: the legacy teleport path is untouched.
        let mut job = base.clone();
        job.topology.transport = TransportConfig {
            enabled: false,
            fabric_gbps: 1.0,
            handoff_budget_tokens: 3,
            handoff_max_agents: 1,
            ..TransportConfig::default()
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with disabled transport"));
        // Disabled open-loop traffic + disabled stochastic faults, dormant
        // knobs cranked: the closed-batch path must not notice them.
        let mut job = base.clone();
        job.topology.open_loop = OpenLoopConfig {
            enabled: false,
            arrival_rate_per_s: 50.0,
            diurnal_amplitude: 1.0,
            patience_s: 0.001,
            high_priority_share: 0.9,
            shed_on_ratio: 0.1,
            shed_off_ratio: 0.05,
            ..OpenLoopConfig::default()
        };
        job.topology.fault_rates = FaultRateConfig {
            enabled: false,
            mtbf_s: 0.001,
            mttr_s: 0.001,
            drain_share: 1.0,
            ..FaultRateConfig::default()
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with disabled open-loop"));
        // Lifetime policy at its default (`Lru`), a fully-dormant
        // workflow config with every knob cranked, and content-hash
        // knobs set on a *disabled* tier: the pre-PR oracle to the bit.
        // Any workflow bookkeeping leaking into the plain fleet (a graph
        // gate on registration, a lifetime stamp, a chunk observation)
        // breaks this immediately.
        let mut job = base.clone();
        job.engine.kv_lifetime = KvLifetimeMode::Lru;
        job.workload.workflow = WorkflowConfig {
            enabled: false,
            graphs: 99,
            fanout_min: 5,
            fanout_max: 9,
            map_reduce_share: 1.0,
            shared_context_tokens: 9_999,
            align_tokens: 16,
            seed: 4242,
        };
        job.topology.prefix_tier = PrefixTierConfig {
            enabled: false,
            content_hash: true,
            hash_chunk_tokens: 32,
            ..PrefixTierConfig::default()
        };
        let got = run_job(&job).unwrap();
        assert_bit_identical(&got, &want, &format!("job {i} with dormant workflow + Lru"));
    }
}

fn routing_job(replicas: usize, router: RouterKind) -> JobConfig {
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: WorkloadConfig {
            n_agents: 32,
            steps_min: 5,
            steps_max: 7,
            ..WorkloadConfig::default()
        },
        // No admission control: isolates pure routing effects (no pauses).
        scheduler: SchedulerKind::Uncontrolled,
        topology: TopologyConfig { replicas, router, ..TopologyConfig::default() },
    }
}

/// PROPERTY: cache-affinity routing at N=4 yields identical results
/// across repeated runs — the cluster loop has no hidden nondeterminism
/// (map iteration order, time ties, router state).
#[test]
fn n4_cluster_runs_are_deterministic() {
    for router in [
        RouterKind::RoundRobin,
        RouterKind::LeastLoaded,
        RouterKind::CacheAffinity,
        RouterKind::Rebalance,
    ] {
        let job = routing_job(4, router);
        let a = run_job(&job).unwrap();
        let b = run_job(&job).unwrap();
        assert_bit_identical(&a, &b, &format!("repeat {router:?} N=4"));
        assert_eq!(a.replicas, 4);
    }
}

/// PROPERTY (differential, tier satellite): with the tier disabled — the
/// default — `run_sharded` output at N=4 is bit-identical to the
/// pre-tier cluster, whatever the disabled tier's other knobs say.  Any
/// tier bookkeeping that leaks into the disabled path (an observe, a
/// maintenance pass, a routing hint) breaks this immediately.
#[test]
fn n4_tier_off_machinery_is_invisible() {
    for router in [RouterKind::CacheAffinity, RouterKind::Rebalance, RouterKind::LeastLoaded] {
        let plain = routing_job(4, router);
        let want = run_job(&plain).unwrap();
        let mut weird = plain.clone();
        weird.topology.prefix_tier = PrefixTierConfig {
            enabled: false,
            hot_after: 2,
            budget_tokens: 999_999,
            min_prefix_tokens: 1,
            ..PrefixTierConfig::default()
        };
        let got = run_job(&weird).unwrap();
        assert_bit_identical(&got, &want, &format!("{router:?} N=4 disabled tier"));
        assert_eq!(got.prefix_tier, Default::default(), "disabled tier must report zeros");
        assert!(got.broadcast_series.is_empty());
    }
}

/// PROPERTY (differential, transport satellite): with `TransportConfig`
/// at defaults — instantaneous visibility, full-ship, drop-on-drain —
/// `run_sharded` output at N=4 is bit-identical to the pre-transport
/// cluster, dormant knobs notwithstanding.  Any transport bookkeeping
/// leaking into the disabled path (a fabric charge, a completion clock
/// stop, a handoff) breaks this immediately.
#[test]
fn n4_transport_off_machinery_is_invisible() {
    for router in [RouterKind::CacheAffinity, RouterKind::Rebalance] {
        let plain = routing_job(4, router);
        let want = run_job(&plain).unwrap();
        let mut dormant = plain.clone();
        dormant.topology.transport = TransportConfig {
            enabled: false,
            fabric_gbps: 0.001,
            handoff_budget_tokens: 1,
            handoff_max_agents: 1,
            ..TransportConfig::default()
        };
        let got = run_job(&dormant).unwrap();
        assert_bit_identical(&got, &want, &format!("{router:?} N=4 disabled transport"));
        assert_eq!(got.transport, Default::default(), "disabled transport must report zeros");
    }
}

/// PROPERTY (differential, open-loop tentpole): with `OpenLoopConfig` and
/// `FaultRateConfig` disabled — the defaults — `run_sharded` output at
/// N=4 is bit-identical to the closed-batch cluster, however the dormant
/// knobs are set.  Any open-loop bookkeeping leaking into the closed path
/// (an arrival clock stop, a latency sample, a governor observation, a
/// sampler draw) breaks this immediately.
#[test]
fn n4_open_loop_off_machinery_is_invisible() {
    for router in [RouterKind::CacheAffinity, RouterKind::Rebalance] {
        let plain = routing_job(4, router);
        let want = run_job(&plain).unwrap();
        let mut dormant = plain.clone();
        dormant.topology.open_loop = OpenLoopConfig {
            enabled: false,
            arrival_rate_per_s: 100.0,
            patience_s: 0.001,
            slo_ttft_s: 0.001,
            slo_step_s: 0.001,
            priority_admission: true,
            shed: true,
            ..OpenLoopConfig::default()
        };
        dormant.topology.fault_rates = FaultRateConfig {
            enabled: false,
            mtbf_s: 0.01,
            mttr_s: 0.01,
            ..FaultRateConfig::default()
        };
        let got = run_job(&dormant).unwrap();
        assert_bit_identical(&got, &want, &format!("{router:?} N=4 disabled open-loop"));
        assert_eq!(got.open_loop, Default::default(), "disabled open-loop must report zeros");
        assert_eq!(got.ttft.count(), 0, "no TTFT samples in a closed-batch run");
        assert_eq!(got.step_latency.count(), 0, "no step-latency samples in a closed-batch run");
    }
}

/// PROPERTY (differential, workflow satellite): with `WorkflowConfig`
/// disabled and `kv_lifetime` at its `Lru` default — however the dormant
/// workflow knobs and the disabled tier's content-hash knobs are set —
/// `run_sharded` output at N=4 is bit-identical to the pre-workflow
/// cluster.  The graph gate on slot registration, the lifetime-hint
/// plumbing and the chunk candidate index must all be invisible until
/// explicitly enabled.
#[test]
fn n4_workflow_off_machinery_is_invisible() {
    for router in [RouterKind::CacheAffinity, RouterKind::Rebalance] {
        let plain = routing_job(4, router);
        let want = run_job(&plain).unwrap();
        let mut dormant = plain.clone();
        dormant.engine.kv_lifetime = KvLifetimeMode::Lru;
        dormant.workload.workflow = WorkflowConfig {
            enabled: false,
            graphs: 64,
            fanout_min: 4,
            fanout_max: 8,
            map_reduce_share: 1.0,
            shared_context_tokens: 4_096,
            align_tokens: 64,
            seed: 999,
        };
        dormant.topology.prefix_tier = PrefixTierConfig {
            enabled: false,
            content_hash: true,
            hash_chunk_tokens: 64,
            ..PrefixTierConfig::default()
        };
        let got = run_job(&dormant).unwrap();
        assert_bit_identical(&got, &want, &format!("{router:?} N=4 dormant workflow"));
    }
}

/// ACCEPTANCE (tier): in the thrashing regime — where LRU pressure
/// repeatedly evicts and re-prefills whole family subtrees — the
/// broadcast tier's pins keep the shared prefixes resident on every
/// replica, recovering cross-agent hits the tier-off fleet structurally
/// loses.  N=4 with 5 task families (coprime: every family splits across
/// all replicas) at paper-depth trajectories, a scaled-down cell of the
/// `prefix_sharing` sweep the nightly bench runs at N∈{1,2,4,8}.
#[test]
fn tier_on_recovers_shared_prefix_hits_under_thrashing() {
    let mut off = routing_job(4, RouterKind::CacheAffinity);
    // Paper-depth contexts: ~16 agents/replica at ~22k final tokens
    // overflow the TP2 pool (~253k slots), so the run genuinely thrashes.
    off.workload = presets::qwen3_workload(64);
    off.workload.task_families = 5;
    off.scheduler = SchedulerKind::Concur(AimdParams::default());
    let mut on = off.clone();
    on.topology.prefix_tier = PrefixTierConfig::on();

    let off = run_job(&off).unwrap();
    let on = run_job(&on).unwrap();
    assert_eq!(off.agents_finished, 64);
    assert_eq!(on.agents_finished, 64);
    assert!(off.counters.evicted_tokens > 0, "scenario must actually thrash");
    assert!(on.prefix_tier.hot_prefixes > 0, "family prefixes must go hot");
    assert!(on.prefix_tier.ships > 0, "hot prefixes must ship");
    assert!(on.counters.broadcast_hit_tokens > 0, "shipped prefixes must be hit");
    assert!(
        on.hit_rate > off.hit_rate,
        "tier on {:.4} must beat tier off {:.4} on lifetime hit rate at N=4",
        on.hit_rate,
        off.hit_rate
    );
    assert_eq!(off.prefix_tier, Default::default());
}

/// The routing claim itself: once agents have warm prefixes to lose,
/// pinning them (cache-affinity) beats per-request load balancing on
/// lifetime hit rate — least-loaded migrates an agent whenever another
/// replica dips below its current one, round-robin migrates every step.
#[test]
fn cache_affinity_beats_balancers_on_hit_rate_at_n4() {
    let aff = run_job(&routing_job(4, RouterKind::CacheAffinity)).unwrap();
    let ll = run_job(&routing_job(4, RouterKind::LeastLoaded)).unwrap();
    let rr = run_job(&routing_job(4, RouterKind::RoundRobin)).unwrap();
    assert!(
        aff.hit_rate >= ll.hit_rate,
        "affinity {:.3} < least-loaded {:.3}",
        aff.hit_rate,
        ll.hit_rate
    );
    assert!(
        aff.hit_rate >= rr.hit_rate,
        "affinity {:.3} < round-robin {:.3}",
        aff.hit_rate,
        rr.hit_rate
    );
    // All routers finish the full fleet either way.
    for r in [&aff, &ll, &rr] {
        assert_eq!(r.agents_finished, 32);
    }
}

/// Sharding sanity: with dense agent ids, cache-affinity at N=4 keeps the
/// per-agent trajectory hit rate close to the single-replica driver (the
/// whole point of pinning) while the balancers pay real misses.
#[test]
fn affinity_preserves_single_replica_hit_rate() {
    let single = run_job(&routing_job(1, RouterKind::CacheAffinity)).unwrap();
    let aff = run_job(&routing_job(4, RouterKind::CacheAffinity)).unwrap();
    // Pinned agents extend the same radix path on their home replica, so
    // sharding may only change hit rate through cross-agent sharing of
    // the system prompt (task families now split across replicas).
    assert!(
        aff.hit_rate > single.hit_rate * 0.9,
        "affinity N=4 {:.3} lost more than 10% of single-replica {:.3}",
        aff.hit_rate,
        single.hit_rate
    );
}

/// PROPERTY (tentpole acceptance): `run_sharded` output is bit-identical
/// across step-worker counts {1, 2, 4} on the **full-stack** config —
/// broadcast tier + asynchronous transport + open-loop traffic +
/// stochastic fault injection all enabled at once — over 5 seeds.  The
/// parallel event-clock merge only changes how ready replicas are
/// stepped between clock stops, never what any of the machinery above
/// observes.  (The CI determinism job pins the same claim end-to-end via
/// `CONCUR_WORKERS` on `concur repro cluster`.)
#[test]
fn full_stack_run_is_bit_identical_across_step_worker_counts() {
    use concur::agent::open_loop_fleet;
    use concur::cluster::{make_router, run_sharded_with_workers};
    use concur::coordinator::make_controller;
    use concur::costmodel::CostModel;
    use concur::engine::SimEngine;

    for seed in 0..5u64 {
        let job = JobConfig {
            cluster: presets::qwen3_cluster(2),
            engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
            workload: WorkloadConfig {
                n_agents: 16,
                steps_min: 3,
                steps_max: 5,
                task_families: 5,
                seed: 40 + seed,
                ..WorkloadConfig::default()
            },
            scheduler: SchedulerKind::Concur(AimdParams::default()),
            topology: TopologyConfig {
                replicas: 3,
                router: RouterKind::CacheAffinity,
                prefix_tier: PrefixTierConfig::on(),
                transport: TransportConfig::on(),
                open_loop: OpenLoopConfig {
                    arrival_rate_per_s: 2.0,
                    seed: 100 + seed,
                    ..OpenLoopConfig::on()
                },
                fault_rates: FaultRateConfig {
                    mtbf_s: 5.0,
                    mttr_s: 2.0,
                    ..FaultRateConfig::on()
                },
                ..TopologyConfig::default()
            },
        };
        job.validate().unwrap();

        let run_at = |step_workers: usize| -> RunResult {
            let n = job.topology.replicas;
            let mut engines: Vec<SimEngine> = (0..n)
                .map(|_| SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone())))
                .collect();
            let mut router = make_router(job.topology.router);
            let agents = open_loop_fleet(&job.workload, &job.topology.open_loop);
            run_sharded_with_workers(
                &mut engines,
                router.as_mut(),
                agents,
                None,
                make_controller(&job.scheduler),
                &job.topology.fault_plan,
                &job.topology.tool_skew,
                &job.topology.prefix_tier,
                &job.topology.transport,
                &job.topology.open_loop,
                &job.topology.fault_rates,
                step_workers,
            )
            .unwrap()
        };

        let sequential = run_at(1);
        // The run must actually exercise the machinery it claims to pin.
        assert!(sequential.open_loop.arrived > 0, "seed {seed}: no open-loop arrivals");
        assert!(
            sequential.faults.stochastic_injected + sequential.faults.stochastic_suppressed > 0,
            "seed {seed}: the fault sampler never drew"
        );
        for workers in [2usize, 4] {
            let parallel = run_at(workers);
            assert_bit_identical(
                &parallel,
                &sequential,
                &format!("seed {seed}, {workers} step workers vs sequential"),
            );
        }
    }
}
