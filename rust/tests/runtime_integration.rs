//! Integration: AOT artifacts → PJRT load → execute → numerics sane.
//!
//! These tests require `make artifacts` to have run (the repo ships the
//! Makefile dependency); they are skipped gracefully when artifacts are
//! missing so `cargo test` works in a fresh checkout too.

use std::path::PathBuf;

use concur::runtime::{ArtifactKind, Manifest, ModelRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn load_and_decode_step_runs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = ModelRuntime::load(&dir).unwrap();
    let g = rt.geometry().clone();
    let mut state = rt.new_state(1).unwrap();
    let out = rt.decode_step(&mut state, &[65]).unwrap();
    assert_eq!(out.logits.len(), g.vocab);
    assert!(out.logits.iter().all(|x| x.is_finite()));
    assert_eq!(state.lens, vec![1]);
    // Another step advances the cache.
    let tok = out.argmax(0);
    let out2 = rt.decode_step(&mut state, &[tok]).unwrap();
    assert_eq!(state.lens, vec![2]);
    assert!(out2.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn decode_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    let run = || {
        let mut st = rt.new_state(2).unwrap();
        let mut toks = vec![10u32, 200u32];
        let mut all = Vec::new();
        for _ in 0..5 {
            let out = rt.decode_step(&mut st, &toks).unwrap();
            toks = vec![out.argmax(0), out.argmax(1)];
            all.extend_from_slice(&toks);
        }
        all
    };
    assert_eq!(run(), run());
}

#[test]
fn extend_then_decode_matches_pure_decode() {
    // The same 8-token prompt fed (a) one token at a time through the
    // decode graph and (b) as a chunk through the extend graph must yield
    // the same next-token logits — the cross-graph consistency the radix
    // reuse path depends on.
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    let prompt: Vec<u32> = vec![72, 101, 108, 108, 111, 32, 119, 111];

    // (a) token-by-token decode.
    let mut st_a = rt.new_state(1).unwrap();
    let mut last_a = None;
    for &t in &prompt {
        last_a = Some(rt.decode_step(&mut st_a, &[t]).unwrap());
    }

    // (b) one extend chunk.
    let chunk = rt.extend_chunk_size(1).unwrap();
    let mut toks = prompt.clone();
    toks.resize(chunk, 0);
    let mut st_b = rt.new_state(1).unwrap();
    let out_b = rt
        .extend_chunk(&mut st_b, &toks, &[prompt.len() as i32])
        .unwrap();

    assert_eq!(st_a.lens, st_b.lens);
    let a = last_a.unwrap();
    let max_diff = a
        .row(0)
        .iter()
        .zip(out_b.row(0))
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "decode vs extend logits differ by {max_diff}");
}

#[test]
fn batch_rows_are_independent() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    // Row 0 same in both runs; row 1 differs → row 0 logits must match.
    let mut st1 = rt.new_state(2).unwrap();
    let mut st2 = rt.new_state(2).unwrap();
    let o1 = rt.decode_step(&mut st1, &[7, 100]).unwrap();
    let o2 = rt.decode_step(&mut st2, &[7, 200]).unwrap();
    let diff0 = o1
        .row(0)
        .iter()
        .zip(o2.row(0))
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(diff0 < 1e-5, "row 0 leaked across batch: {diff0}");
    let diff1 = o1
        .row(1)
        .iter()
        .zip(o2.row(1))
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(diff1 > 1e-3, "row 1 should differ");
}

#[test]
fn manifest_covers_decode_and_extend_ladders() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let d = m.batches(ArtifactKind::Decode);
    let e = m.batches(ArtifactKind::Extend);
    assert!(d.contains(&1) && d.contains(&8));
    assert!(e.contains(&1) && e.contains(&8));
}
