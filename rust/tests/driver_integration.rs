//! End-to-end driver integration: full jobs across all schedulers,
//! asserting the paper's qualitative claims hold on this substrate.

use concur::config::{
    presets, AimdParams, EngineConfig, EvictionMode, JobConfig, SchedulerKind,
    TopologyConfig, WorkloadConfig,
};
use concur::driver::run_job;
use concur::metrics::Phase;

fn job(scheduler: SchedulerKind, eviction: EvictionMode, n_agents: usize) -> JobConfig {
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, eviction, ..EngineConfig::default() },
        workload: WorkloadConfig { n_agents, ..WorkloadConfig::default() },
        scheduler,
        topology: TopologyConfig::default(),
    }
}

#[test]
fn all_schedulers_complete_the_same_workload() {
    for scheduler in [
        SchedulerKind::Uncontrolled,
        SchedulerKind::RequestCap(8),
        SchedulerKind::AgentCap(12),
        SchedulerKind::Concur(AimdParams::default()),
    ] {
        let r = run_job(&job(scheduler.clone(), EvictionMode::Discard, 32)).unwrap();
        assert_eq!(r.agents_finished, 32, "{:?} lost agents", scheduler.name());
        // Identical predetermined trajectories → identical token totals.
        assert_eq!(r.counters.decode_tokens >= r.total_gen_tokens, true);
    }
}

#[test]
fn concur_beats_uncontrolled_under_memory_pressure() {
    // The headline claim at unit scale: 64 agents on the TP2 pool.
    let base = run_job(&job(SchedulerKind::Uncontrolled, EvictionMode::Discard, 64))
        .unwrap();
    let conc = run_job(&job(
        SchedulerKind::Concur(AimdParams::default()),
        EvictionMode::Discard,
        64,
    ))
    .unwrap();
    assert!(
        conc.total_time < base.total_time,
        "CONCUR {} !< SGLang {}",
        conc.total_time,
        base.total_time
    );
    assert!(conc.hit_rate > base.hit_rate + 0.2);
    assert!(
        conc.breakdown.fraction(Phase::Recompute)
            < base.breakdown.fraction(Phase::Recompute)
    );
}

#[test]
fn no_pressure_means_no_controller_penalty() {
    // With a small fleet on the TP8 pool nothing thrashes; CONCUR must not
    // cost more than a few percent vs uncontrolled.
    let mk = |s| JobConfig {
        cluster: presets::qwen3_cluster(8),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: WorkloadConfig { n_agents: 8, ..WorkloadConfig::default() },
        scheduler: s,
        topology: TopologyConfig::default(),
    };
    let base = run_job(&mk(SchedulerKind::Uncontrolled)).unwrap();
    let conc = run_job(&mk(SchedulerKind::Concur(AimdParams::default()))).unwrap();
    let ratio = conc.total_time.as_secs_f64() / base.total_time.as_secs_f64();
    assert!(ratio < 1.25, "CONCUR overhead without pressure: {ratio:.2}x");
}

#[test]
fn deterministic_end_to_end() {
    let j = job(SchedulerKind::Concur(AimdParams::default()), EvictionMode::Discard, 24);
    let a = run_job(&j).unwrap();
    let b = run_job(&j).unwrap();
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.counters.evicted_tokens, b.counters.evicted_tokens);
    assert_eq!(a.pauses, b.pauses);
    assert_eq!(a.engine_steps, b.engine_steps);
}

#[test]
fn hicache_trades_hit_rate_for_link_time() {
    let base = run_job(&job(SchedulerKind::Uncontrolled, EvictionMode::Discard, 64))
        .unwrap();
    let hic = run_job(&job(SchedulerKind::Uncontrolled, EvictionMode::Offload, 64))
        .unwrap();
    // Offload retains cache → higher hit rate than discard...
    assert!(hic.hit_rate > base.hit_rate);
    // ...and pays for it in reload traffic.
    assert!(hic.counters.reloaded_tokens > 0);
}

#[test]
fn breakdown_accounts_for_all_wall_time_categories() {
    let r = run_job(&job(
        SchedulerKind::Concur(AimdParams::default()),
        EvictionMode::Discard,
        4, // small fleet: the engine actually idles during tool calls
    ))
    .unwrap();
    let total = r.breakdown.total();
    assert!(total.0 > 0);
    // Decode must dominate prefill for generation-heavy agentic loops.
    assert!(r.breakdown.get(Phase::Decode) > r.breakdown.get(Phase::Prefill));
    // Tool waiting appears (with 4 agents the engine goes idle between steps).
    assert!(r.breakdown.get(Phase::ToolWait).0 > 0);
}

#[test]
fn window_series_tracks_slots_not_offered_load() {
    let r = run_job(&job(
        SchedulerKind::Concur(AimdParams::default()),
        EvictionMode::Discard,
        48,
    ))
    .unwrap();
    // After a cut, active agents drain down to the window at step
    // boundaries only (execution continuity) — so active may transiently
    // exceed the *current* window but never the running-max window, and
    // grants never push it above the window.
    let mut peak_w = 0f64;
    for ((_, w), (_, a)) in r
        .window_series
        .points()
        .iter()
        .zip(r.active_series.points())
    {
        if !w.is_nan() {
            peak_w = peak_w.max(*w);
            assert!(*a <= peak_w + 1.0, "active {a} > peak window {peak_w}");
        }
    }
    // The drain is real: the run ends with active at or below the window.
    let last_w = r.window_series.last().unwrap();
    let last_a = r.active_series.last().unwrap();
    assert!(last_a <= last_w + 1.0);
}
