//! Shared scaffolding for the integration suites (`mod common;` from
//! each test binary).
//!
//! Lives here so `cluster_integration.rs`, `transport_integration.rs`,
//! `openloop_integration.rs`, `workflow_integration.rs` and
//! `storage_integration.rs` stop copy-pasting the same three things:
//!
//! * [`reference`] / [`reference_run`] — the pre-refactor single-engine
//!   driver, embedded verbatim as the behavioral oracle every
//!   feature-off differential compares against;
//! * [`assert_bit_identical`] — the exhaustive `RunResult` comparison
//!   (every counter, series point, histogram moment and per-agent
//!   record, float fields compared by bits);
//! * job builders ([`small_cluster_job`], [`random_jobs`]) — the
//!   anchored workload recipes the suites perturb.
//!
//! Each binary uses a subset, hence the file-wide `dead_code` allow.
#![allow(dead_code)]

use concur::config::presets;
use concur::config::{
    AimdParams, EngineConfig, EvictionMode, JobConfig, RouterKind, SchedulerKind,
    TopologyConfig, WorkloadConfig,
};
use concur::core::Rng;
use concur::driver::RunResult;
use concur::metrics::ALL_PHASES;

/// Pre-refactor driver, embedded verbatim as the behavioral oracle (only
/// the `crate::` paths and the RunResult's new replica/fault fields
/// adapted — a single-engine run has no faults and one always-admissible
/// replica).
pub mod reference {
    use concur::agent::Agent;
    use concur::cluster::{FaultStats, OpenLoopStats, PrefixTierStats, TransportStats};
    use concur::coordinator::slots::BoundaryDecision;
    use concur::coordinator::{ControlInputs, Controller, SlotManager};
    use concur::core::{AgentId, Micros, RequestId};
    use concur::driver::{AgentOutcome, RunResult};
    use concur::engine::SimEngine;
    use concur::metrics::{Histogram, Phase, ProfileSnapshot, TimeSeries};
    use concur::sim::{EventQueue, SimClock};

    pub fn run_with(
        engine: &mut SimEngine,
        agents: Vec<Agent>,
        mut controller: Box<dyn Controller>,
    ) -> RunResult {
        if let Some(cap) = controller.engine_request_cap() {
            engine.cfg.max_running = cap;
        }

        let mut slots = SlotManager::new();
        let total_gen: u64 = agents.iter().map(|a| a.total_gen_tokens()).sum();
        let agents_total = agents.len();
        let mut fleet: Vec<Agent> = agents;
        fleet.sort_by_key(|a| a.id.0);
        for (i, a) in fleet.iter().enumerate() {
            assert_eq!(a.id.0 as usize, i, "driver requires dense agent ids");
            slots.register(a.id);
        }
        fn agent(fleet: &mut [Agent], id: AgentId) -> &mut Agent {
            &mut fleet[id.0 as usize]
        }
        let mut active_footprint: u64 = 0;

        let mut clock = SimClock::new();
        let mut events: EventQueue<AgentId> = EventQueue::new();
        let mut next_req: u64 = 0;
        let mut result_breakdown_toolwait = Micros::ZERO;

        let mut usage_series = TimeSeries::new("kv_usage");
        let mut hit_series = TimeSeries::new("hit_rate");
        let mut active_series = TimeSeries::new("active_agents");
        let mut window_series = TimeSeries::new("window");
        let mut agent_latency = Histogram::new("agent_e2e_latency");
        let mut alive_series = TimeSeries::new("admissible_replicas");
        alive_series.record(Micros::ZERO, 1.0);
        let mut per_agent: Vec<AgentOutcome> = Vec::with_capacity(agents_total);

        let mut finished_agents = 0usize;
        let mut engine_steps = 0u64;

        loop {
            let now = clock.now();

            // 1. Deliver due tool completions; paused agents wait.
            while let Some((_, aid)) = events.pop_due(now) {
                let a = agent(&mut fleet, aid);
                a.on_tool_done();
                if slots.on_step_boundary(aid, controller.window())
                    == BoundaryDecision::Continue
                {
                    let req = a.make_request(RequestId(next_req), now);
                    next_req += 1;
                    engine.submit(req);
                } else {
                    active_footprint -= a.context_len() as u64; // paused
                }
            }

            // 2. Grant freed slots (resume paused LIFO, admit fresh FIFO).
            for aid in slots.grant_up_to(controller.window()) {
                let a = agent(&mut fleet, aid);
                active_footprint += a.context_len() as u64;
                let req = a.make_request(RequestId(next_req), now);
                next_req += 1;
                engine.submit(req);
            }

            // 3. Advance: engine iteration, or jump to the next event.
            if engine.has_work() {
                let out = engine.step(now);
                engine_steps += 1;
                clock.advance(Micros(out.duration.0.max(1)));
                let after = clock.now();

                for fin in out.finished {
                    let a = agent(&mut fleet, fin.agent);
                    let before = a.context_len() as u64;
                    match a.on_step_finished(&fin.output, after) {
                        Some(tool_latency) => {
                            active_footprint += a.context_len() as u64 - before;
                            events.push(after + tool_latency, fin.agent);
                        }
                        None => {
                            active_footprint -= before; // slot released
                            slots.release(fin.agent);
                            finished_agents += 1;
                            let start = a.started_at.unwrap_or(Micros::ZERO);
                            agent_latency.record(after.saturating_sub(start));
                            per_agent.push(AgentOutcome {
                                agent: fin.agent,
                                gen_tokens: a.total_gen_tokens(),
                                finished_at: after,
                            });
                        }
                    }
                }

                let sig = engine.signals();
                controller.on_signals(&ControlInputs {
                    engine: sig,
                    active_agents: slots.active_count(),
                    active_footprint,
                    capacity: engine.pool().capacity(),
                });
                usage_series.record(after, sig.pool_usage);
                hit_series.record(after, sig.hit_rate);
                active_series.record(after, slots.active_count() as f64);
                let w = controller.window();
                window_series.record(after, if w == usize::MAX { f64::NAN } else { w as f64 });
            } else if let Some(t) = events.peek_time() {
                result_breakdown_toolwait += t.saturating_sub(now);
                clock.advance_to(t);
            } else {
                break; // no engine work, no future events → done
            }
        }

        assert_eq!(finished_agents, agents_total, "reference run incomplete");

        let total_time = clock.now();
        let mut breakdown = std::mem::take(&mut engine.breakdown);
        breakdown.add(Phase::ToolWait, result_breakdown_toolwait);
        let throughput_tps = if total_time.0 > 0 {
            total_gen as f64 / total_time.as_secs_f64()
        } else {
            0.0
        };

        RunResult {
            scheduler: controller.name(),
            total_time,
            breakdown,
            hit_rate: engine.lifetime_hits.ratio(),
            counters: engine.counters,
            usage_series,
            hit_series,
            active_series,
            window_series,
            agents_total,
            agents_finished: finished_agents,
            total_gen_tokens: total_gen,
            throughput_tps,
            agent_latency,
            engine_steps,
            pauses: slots.pauses,
            resumes: slots.resumes,
            replicas: 1,
            router: "single".into(),
            faults: FaultStats::default(),
            alive_series,
            per_agent,
            prefix_tier: PrefixTierStats::default(),
            broadcast_series: TimeSeries::new("broadcast_shipped_tokens"),
            transport: TransportStats::default(),
            ttft: Histogram::new("ttft"),
            step_latency: Histogram::new("step_latency"),
            open_loop: OpenLoopStats::default(),
            profile: ProfileSnapshot::default(),
        }
    }
}

/// Run `job` through the embedded pre-refactor driver.
pub fn reference_run(job: &JobConfig) -> RunResult {
    use concur::agent::WorkloadGenerator;
    use concur::coordinator::make_controller;
    use concur::costmodel::CostModel;
    use concur::engine::SimEngine;

    job.validate().unwrap();
    let agents = WorkloadGenerator::new(job.workload.clone()).generate();
    let controller = make_controller(&job.scheduler);
    let mut engine = SimEngine::new(job.engine.clone(), CostModel::new(job.cluster.clone()));
    reference::run_with(&mut engine, agents, controller)
}

/// Bitwise comparison of everything a RunResult records (NaN-tolerant for
/// the window series: unbounded windows record NaN points).
pub fn assert_bit_identical(a: &RunResult, b: &RunResult, ctx: &str) {
    assert_eq!(a.scheduler, b.scheduler, "{ctx}: scheduler");
    assert_eq!(a.total_time, b.total_time, "{ctx}: total_time");
    assert_eq!(a.counters, b.counters, "{ctx}: counters");
    assert_eq!(a.hit_rate.to_bits(), b.hit_rate.to_bits(), "{ctx}: hit_rate");
    assert_eq!(a.throughput_tps.to_bits(), b.throughput_tps.to_bits(), "{ctx}: throughput");
    assert_eq!(a.engine_steps, b.engine_steps, "{ctx}: engine_steps");
    assert_eq!(a.agents_finished, b.agents_finished, "{ctx}: agents_finished");
    assert_eq!(a.total_gen_tokens, b.total_gen_tokens, "{ctx}: gen tokens");
    assert_eq!(a.pauses, b.pauses, "{ctx}: pauses");
    assert_eq!(a.resumes, b.resumes, "{ctx}: resumes");
    for p in ALL_PHASES {
        assert_eq!(a.breakdown.get(p), b.breakdown.get(p), "{ctx}: breakdown {}", p.name());
    }
    assert_eq!(a.faults, b.faults, "{ctx}: fault stats");
    assert_eq!(a.prefix_tier, b.prefix_tier, "{ctx}: prefix-tier stats");
    assert_eq!(a.transport, b.transport, "{ctx}: transport stats");
    assert_eq!(a.per_agent, b.per_agent, "{ctx}: per-agent records");
    for (name, sa, sb) in [
        ("usage", &a.usage_series, &b.usage_series),
        ("hit", &a.hit_series, &b.hit_series),
        ("active", &a.active_series, &b.active_series),
        ("window", &a.window_series, &b.window_series),
        ("alive", &a.alive_series, &b.alive_series),
        ("broadcast", &a.broadcast_series, &b.broadcast_series),
    ] {
        assert_eq!(sa.len(), sb.len(), "{ctx}: {name} series length");
        for (pa, pb) in sa.points().iter().zip(sb.points()) {
            assert_eq!(pa.0, pb.0, "{ctx}: {name} series timestamp");
            assert_eq!(pa.1.to_bits(), pb.1.to_bits(), "{ctx}: {name} series value");
        }
    }
    assert_eq!(a.agent_latency.count(), b.agent_latency.count(), "{ctx}: latency n");
    assert_eq!(a.agent_latency.mean(), b.agent_latency.mean(), "{ctx}: latency mean");
    assert_eq!(a.agent_latency.max(), b.agent_latency.max(), "{ctx}: latency max");
    assert_eq!(a.open_loop, b.open_loop, "{ctx}: open-loop stats");
    for (name, ha, hb) in [("ttft", &a.ttft, &b.ttft), ("step", &a.step_latency, &b.step_latency)] {
        assert_eq!(ha.count(), hb.count(), "{ctx}: {name} n");
        assert_eq!(ha.mean(), hb.mean(), "{ctx}: {name} mean");
        assert_eq!(ha.max(), hb.max(), "{ctx}: {name} max");
    }
}

/// Seeded random small jobs across schedulers and eviction modes (same
/// recipe as the parallel-sweep proptest).
pub fn random_jobs(n: usize) -> Vec<JobConfig> {
    let mut rng = Rng::new(0xD1FF);
    (0..n)
        .map(|i| {
            let scheduler = match i % 4 {
                0 => SchedulerKind::Uncontrolled,
                1 => SchedulerKind::Concur(AimdParams::default()),
                2 => SchedulerKind::AgentCap(rng.gen_range(2, 6) as usize),
                _ => SchedulerKind::RequestCap(rng.gen_range(2, 6) as usize),
            };
            let eviction = if rng.chance(0.5) {
                EvictionMode::Discard
            } else {
                EvictionMode::Offload
            };
            JobConfig {
                cluster: presets::qwen3_cluster(8),
                engine: EngineConfig {
                    eviction,
                    hit_window: 8,
                    ..EngineConfig::default()
                },
                workload: WorkloadConfig {
                    n_agents: rng.gen_range(4, 12) as usize,
                    steps_min: 2,
                    steps_max: 4,
                    seed: rng.gen_range(1, 1_000),
                    ..WorkloadConfig::default()
                },
                scheduler,
                topology: TopologyConfig::default(),
            }
        })
        .collect()
}

/// The anchored small-cluster job the multi-replica suites share: a
/// Qwen3-class TP2 cluster, responsive hit window, CONCUR admission and
/// a short 5-family fleet.  Each suite then enables the machinery it
/// actually tests (tier, transport, open-loop, workflow) on top.
pub fn small_cluster_job(n_agents: usize, replicas: usize, router: RouterKind) -> JobConfig {
    JobConfig {
        cluster: presets::qwen3_cluster(2),
        engine: EngineConfig { hit_window: 8, ..EngineConfig::default() },
        workload: WorkloadConfig {
            n_agents,
            steps_min: 3,
            steps_max: 5,
            task_families: 5,
            ..WorkloadConfig::default()
        },
        scheduler: SchedulerKind::Concur(AimdParams::default()),
        topology: TopologyConfig { replicas, router, ..TopologyConfig::default() },
    }
}
