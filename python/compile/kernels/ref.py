"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground-truth implementations that the Pallas kernels in
``attention.py`` are validated against (pytest + hypothesis in
``python/tests/``).  They are deliberately written in the most obvious
way — full score matrices, explicit masks — so that any cleverness in the
kernels (online softmax, block tiling, length masking) is checked against
un-clever math.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Single-token (decode-step) attention against a KV cache.

    Args:
      q:        [B, H, D]   query for the new token, one per sequence.
      k_cache:  [B, T, H, D] key cache (only the first ``lengths[b]`` rows
                of sequence ``b`` are valid).
      v_cache:  [B, T, H, D] value cache.
      lengths:  [B] int32   number of valid cache entries per sequence,
                *including* the slot for the current token.

    Returns:
      [B, H, D] attention output.
    """
    B, T, H, D = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, dtype=q.dtype))
    # scores: [B, H, T]
    scores = jnp.einsum("bhd,bthd->bht", q, k_cache) * scale
    pos = jnp.arange(T)[None, None, :]  # [1, 1, T]
    mask = pos < lengths[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bht,bthd->bhd", probs, v_cache)


def prefill_attention_ref(q, k, v, lengths):
    """Causal self-attention over a (possibly padded) prompt chunk.

    Args:
      q, k, v:  [B, T, H, D]
      lengths:  [B] int32  valid prompt length per sequence; rows at or
                beyond the length attend only to themselves (their output
                is garbage and masked out downstream).

    Returns:
      [B, T, H, D]
    """
    B, T, H, D = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, dtype=q.dtype))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    qpos = jnp.arange(T)[None, None, :, None]
    kpos = jnp.arange(T)[None, None, None, :]
    causal = kpos <= qpos
    valid = kpos < lengths[:, None, None, None]
    mask = causal & valid
    # Every query row always sees at least itself (kpos == qpos) so the
    # softmax below is well defined even for padded rows.
    mask = mask | (kpos == qpos)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)
