"""L1 Pallas attention kernels (flash-style, length-masked, TPU-shaped).

These are the compute hot-spots of the serving path: decode-step attention
against a KV cache and causal prefill attention.  Both are written in the
TPU Pallas model and validated under ``interpret=True`` (the CPU PJRT
plugin cannot execute Mosaic custom-calls, see DESIGN.md §Hardware-Adaptation).

Hardware adaptation of the paper's GPU framing:

* A CUDA flash-attention kernel assigns one *threadblock* per (batch, head,
  q-tile) and stages K/V tiles through shared memory.  Here the same
  schedule is expressed with the Pallas ``grid`` (one program per
  (batch, head[, q-tile])) and ``BlockSpec`` index maps describing which
  HBM tile is staged into VMEM for each program.
* Online-softmax accumulation keeps the working set at O(block) — no
  [T, T] score matrix ever exists, which is exactly the property that makes
  KV recompute (the paper's "retransmission") quadratic in *prefill* cost
  but linear in kernel memory.
* Contractions are shaped (q_block x D) @ (D x k_block) with D and blocks
  multiples of the (8, 128) MXU tile where possible, f32 accumulation.

VMEM footprint per program (see DESIGN.md §Perf):
  decode : D + 2*K_BLOCK*D floats        (q row + one K and one V tile)
  prefill: Q_BLOCK*D + 2*K_BLOCK*D + Q_BLOCK*K_BLOCK floats
With the default blocks (Q_BLOCK=K_BLOCK=128, D<=128) both stay well under
1 MiB — far below the ~16 MiB VMEM budget, leaving room for the compiler
to double-buffer the K/V tile streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Default sequence tile staged HBM->VMEM per inner step. 128 matches the
# MXU lane width; both kernels accept any T that is a multiple of the block.
K_BLOCK = 128
Q_BLOCK = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, k_block: int):
    """One program per (batch, head): q row vs the full cached sequence.

    Refs (shapes are the per-program VMEM blocks; size-1 batch/head dims
    are squeezed away by the ``None`` entries in the BlockSpecs):
      len_ref: [1]      valid cache length for this sequence
      q_ref:   [D]      query row
      k_ref:   [T, D]   key cache for this (b, h)
      v_ref:   [T, D]   value cache for this (b, h)
      o_ref:   [D]      output row
    """
    T, D = k_ref.shape
    length = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    q = q_ref[...] * scale  # [D]

    nblocks = T // k_block

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_tile = k_ref[pl.ds(i * k_block, k_block), :]  # [KB, D]
        v_tile = v_ref[pl.ds(i * k_block, k_block), :]  # [KB, D]
        scores = k_tile @ q  # [KB]
        pos = i * k_block + jax.lax.iota(jnp.int32, k_block)
        scores = jnp.where(pos < length, scores, NEG_INF)
        # online softmax update
        m_new = jnp.maximum(m_prev, scores.max())
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)  # [KB]
        l_new = l_prev * alpha + p.sum()
        acc = acc * alpha + p @ v_tile  # [D]
        return m_new, l_new, acc

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((D,), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, acc0))
    o_ref[...] = acc / l


def decode_attention(q, k_cache, v_cache, lengths, *, k_block: int = K_BLOCK):
    """Flash decode attention: one new query token per sequence.

    Args:
      q:        [B, H, D] float32
      k_cache:  [B, T, H, D] float32, T a multiple of ``k_block``
      v_cache:  [B, T, H, D] float32
      lengths:  [B] int32, 1 <= lengths[b] <= T

    Returns:
      [B, H, D] float32
    """
    B, T, H, D = k_cache.shape
    if T % k_block != 0:
        raise ValueError(f"T={T} must be a multiple of k_block={k_block}")
    kernel = functools.partial(_decode_kernel, k_block=k_block)
    return pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (b,)),  # lengths
            pl.BlockSpec((None, None, D), lambda b, h: (b, h, 0)),  # q
            pl.BlockSpec((None, T, None, D), lambda b, h: (b, 0, h, 0)),  # k
            pl.BlockSpec((None, T, None, D), lambda b, h: (b, 0, h, 0)),  # v
        ],
        out_specs=pl.BlockSpec((None, None, D), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), jnp.float32),
        interpret=True,
    )(lengths, q, k_cache, v_cache)


def _prefill_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, *, q_block: int, k_block: int
):
    """One program per (batch, head, q-tile): causal flash attention.

    Refs:
      len_ref: [1]            valid prompt length for this sequence
      q_ref:   [QB, D]        query tile
      k_ref:   [T, D]         full key sequence for this (b, h)
      v_ref:   [T, D]
      o_ref:   [QB, D]
    """
    T, D = k_ref.shape
    qi = pl.program_id(2)
    length = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    q = q_ref[...] * scale  # [QB, D]
    qpos = qi * q_block + jax.lax.iota(jnp.int32, q_block)  # [QB]
    total_blocks = T // k_block

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_tile = k_ref[pl.ds(i * k_block, k_block), :]  # [KB, D]
        v_tile = v_ref[pl.ds(i * k_block, k_block), :]
        scores = q @ k_tile.T  # [QB, KB] — MXU-shaped contraction
        kpos = i * k_block + jax.lax.iota(jnp.int32, k_block)  # [KB]
        causal = kpos[None, :] <= qpos[:, None]
        valid = kpos[None, :] < length
        diag = kpos[None, :] == qpos[:, None]
        mask = (causal & valid) | diag
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m_prev, scores.max(axis=1))  # [QB]
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])  # [QB, KB]
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v_tile
        return m_new, l_new, acc

    m0 = jnp.full((q_block,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_block,), jnp.float32)
    acc0 = jnp.zeros((q_block, D), jnp.float32)
    # Only iterate over k-tiles that can be visible to this q-tile.
    upper = jnp.minimum(qi + 1, total_blocks)
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, acc0))
    o_ref[...] = acc / l[:, None]


def prefill_attention(
    q, k, v, lengths, *, q_block: int = Q_BLOCK, k_block: int = K_BLOCK
):
    """Causal flash prefill attention over padded prompt chunks.

    Args:
      q, k, v:  [B, T, H, D] float32, T a multiple of both blocks
      lengths:  [B] int32 valid prompt lengths (padded rows attend to
                themselves only; their output is masked downstream)

    Returns:
      [B, T, H, D] float32
    """
    B, T, H, D = q.shape
    if T % q_block != 0 or T % k_block != 0:
        raise ValueError(f"T={T} must be a multiple of q_block and k_block")
    kernel = functools.partial(_prefill_kernel, q_block=q_block, k_block=k_block)
    grid = (B, H, T // q_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, qi: (b,)),
            pl.BlockSpec((None, q_block, None, D), lambda b, h, qi: (b, qi, h, 0)),
            pl.BlockSpec((None, T, None, D), lambda b, h, qi: (b, 0, h, 0)),
            pl.BlockSpec((None, T, None, D), lambda b, h, qi: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, q_block, None, D), lambda b, h, qi: (b, qi, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, T, H, D), jnp.float32),
        interpret=True,
    )(lengths, q, k, v)


def _extend_kernel(
    clen_ref, q_ref, k_ref, v_ref, o_ref, *, q_block: int, k_block: int
):
    """One program per (batch, head, q-tile) of an *extend* step.

    The chunk's new K/V rows have already been written into the cache at
    positions ``clen .. clen+C``; query row ``j`` of the chunk sits at
    absolute position ``clen + j`` and attends to every cache position
    ``<= clen + j``.  This generalizes prefill (clen=0) and decode (C=1)
    and is what makes radix-cache hits cheap: only the uncached suffix is
    ever run through this kernel.

    Refs:
      clen_ref: [1]     cached-prefix length for this sequence
      q_ref:    [QB, D] query tile (chunk-local)
      k_ref:    [T, D]  full key cache for this (b, h)
      v_ref:    [T, D]
      o_ref:    [QB, D]
    """
    T, D = k_ref.shape
    qi = pl.program_id(2)
    clen = clen_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    q = q_ref[...] * scale  # [QB, D]
    # Absolute positions of this query tile.
    qpos = clen + qi * q_block + jax.lax.iota(jnp.int32, q_block)  # [QB]
    total_blocks = T // k_block

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_tile = k_ref[pl.ds(i * k_block, k_block), :]
        v_tile = v_ref[pl.ds(i * k_block, k_block), :]
        scores = q @ k_tile.T  # [QB, KB]
        kpos = i * k_block + jax.lax.iota(jnp.int32, k_block)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m_prev, scores.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v_tile
        return m_new, l_new, acc

    m0 = jnp.full((q_block,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_block,), jnp.float32)
    acc0 = jnp.zeros((q_block, D), jnp.float32)
    # Only k-tiles up to the last visible position matter.
    last_pos = clen + (qi + 1) * q_block  # exclusive
    upper = jnp.minimum((last_pos + k_block - 1) // k_block, total_blocks)
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, acc0))
    o_ref[...] = acc / l[:, None]


def extend_attention(
    q, k_cache, v_cache, cache_lens, *, q_block: int = Q_BLOCK, k_block: int = K_BLOCK
):
    """Chunked-extend flash attention against a KV cache with a cached prefix.

    Args:
      q:          [B, C, H, D] float32 queries for the new chunk
                  (C a multiple of ``q_block``)
      k_cache:    [B, T, H, D] float32 — new chunk K rows already written at
                  ``cache_lens[b] .. cache_lens[b]+C``
      v_cache:    [B, T, H, D] float32
      cache_lens: [B] int32 cached-prefix length per sequence
                  (``cache_lens[b] + C <= T``); padded chunk rows attend to
                  stale cache garbage — mask their outputs downstream.

    Returns:
      [B, C, H, D] float32
    """
    B, C, H, D = q.shape
    _, T, _, _ = k_cache.shape
    if C % q_block != 0 or T % k_block != 0:
        raise ValueError(f"C={C}/T={T} must be multiples of the blocks")
    kernel = functools.partial(_extend_kernel, q_block=q_block, k_block=k_block)
    return pl.pallas_call(
        kernel,
        grid=(B, H, C // q_block),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, qi: (b,)),
            pl.BlockSpec((None, q_block, None, D), lambda b, h, qi: (b, qi, h, 0)),
            pl.BlockSpec((None, T, None, D), lambda b, h, qi: (b, 0, h, 0)),
            pl.BlockSpec((None, T, None, D), lambda b, h, qi: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, q_block, None, D), lambda b, h, qi: (b, qi, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, C, H, D), jnp.float32),
        interpret=True,
    )(cache_lens, q, k_cache, v_cache)
