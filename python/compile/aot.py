"""AOT compile path: lower the L2 graphs to HLO *text* for the rust runtime.

Emits into ``artifacts/``:

* ``decode_b{B}.hlo.txt``      — one-token decode step, batch B
* ``extend_b{B}_c{C}.hlo.txt`` — C-token chunked extend (prefill / resume)
* ``params.bin``               — flat f32 little-endian parameter vector
* ``manifest.json``            — model geometry + artifact index consumed by
                                 ``rust/src/runtime/artifacts.rs``
* ``model.hlo.txt``            — alias of the default decode graph (Makefile
                                 freshness stamp)

HLO TEXT is the interchange format, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md.

Python runs ONCE here (``make artifacts``); it is never on the request path.
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib

# Batch variants compiled for the serving engine.  The rust batcher rounds
# every scheduled batch up to the nearest compiled size (padding with inert
# sequences), so this ladder is the engine's batch-size granularity.
DECODE_BATCHES = (1, 2, 4, 8)
EXTEND_VARIANTS = ((1, 128), (2, 128), (4, 128), (8, 128))


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_decode(cfg: model_lib.ModelConfig, batch: int):
    c = cfg
    fn = functools.partial(model_lib.decode_step, c)
    kv = jax.ShapeDtypeStruct(
        (c.n_layers, batch, c.max_seq, c.n_heads, c.head_dim), jnp.float32
    )
    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((c.n_params(),), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        kv,
        kv,
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )


def lower_extend(cfg: model_lib.ModelConfig, batch: int, chunk: int):
    c = cfg
    fn = functools.partial(model_lib.extend_chunk, c)
    kv = jax.ShapeDtypeStruct(
        (c.n_layers, batch, c.max_seq, c.n_heads, c.head_dim), jnp.float32
    )
    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((c.n_params(),), jnp.float32),
        jax.ShapeDtypeStruct((batch, chunk), jnp.int32),
        kv,
        kv,
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="stamp path; artifacts land in its directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out).parent
    out_dir.mkdir(parents=True, exist_ok=True)
    cfg = model_lib.ModelConfig()

    params = model_lib.init_params(cfg, seed=args.seed)
    (out_dir / "params.bin").write_bytes(params.astype("<f4").tobytes())
    print(f"params.bin: {params.size} f32 ({params.nbytes / 1e6:.1f} MB)")

    artifacts = []
    for b in DECODE_BATCHES:
        t0 = time.time()
        text = to_hlo_text(lower_decode(cfg, b))
        name = f"decode_b{b}.hlo.txt"
        (out_dir / name).write_text(text)
        artifacts.append({"kind": "decode", "batch": b, "chunk": 1, "file": name})
        print(f"{name}: {len(text)} chars in {time.time() - t0:.1f}s")
    for b, chunk in EXTEND_VARIANTS:
        t0 = time.time()
        text = to_hlo_text(lower_extend(cfg, b, chunk))
        name = f"extend_b{b}_c{chunk}.hlo.txt"
        (out_dir / name).write_text(text)
        artifacts.append({"kind": "extend", "batch": b, "chunk": chunk, "file": name})
        print(f"{name}: {len(text)} chars in {time.time() - t0:.1f}s")

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "n_params": cfg.n_params(),
            "seed": args.seed,
        },
        "params_file": "params.bin",
        # Input order shared by both graph kinds; decode drops chunk_lens.
        "decode_inputs": ["params", "tokens", "k_cache", "v_cache", "cache_lens"],
        "extend_inputs": [
            "params", "tokens", "k_cache", "v_cache", "cache_lens", "chunk_lens",
        ],
        "outputs": ["logits", "k_cache", "v_cache", "cache_lens"],
        "artifacts": artifacts,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))

    # Makefile freshness stamp — alias of the smallest decode graph.
    stamp = (out_dir / "decode_b1.hlo.txt").read_text()
    pathlib.Path(args.out).write_text(stamp)
    print(f"manifest + stamp written to {out_dir}")


if __name__ == "__main__":
    main()
