"""L2: JAX transformer decoder used by the real-model serving path.

A small byte-level decoder-only transformer whose attention runs through the
L1 Pallas kernels (``kernels/attention.py``).  Two entry points are lowered
AOT (``aot.py``) and executed from rust via PJRT:

* ``decode_step``  — one token per sequence against the KV cache
                     (uses the flash *decode* kernel, C=1, no q padding);
* ``extend_chunk`` — append a chunk of C tokens per sequence (prefill and
                     radix-cache-hit resume: only the uncached suffix is
                     computed; uses the *extend* kernel).

Parameters travel as ONE flat f32 vector input so the rust side only needs
``artifacts/params.bin`` (+ shapes in ``manifest.json``); nothing is baked
into the HLO text.  The KV cache is a pair of [L, B, T, H, D] arrays owned
by rust between calls — graphs are pure functions cache -> cache'.

Python never runs at serving time; this module exists only under
``make artifacts`` and pytest.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Geometry of the tiny served model (byte-level vocab)."""

    vocab: int = 256
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 2
    head_dim: int = 64
    d_ff: int = 512
    max_seq: int = 256  # KV cache capacity per sequence (multiple of 128)

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    def param_specs(self) -> list[tuple[str, tuple[int, ...]]]:
        """Ordered (name, shape) layout of the flat parameter vector."""
        c = self
        specs: list[tuple[str, tuple[int, ...]]] = [
            ("embed", (c.vocab, c.d_model)),
            ("pos_embed", (c.max_seq, c.d_model)),
        ]
        for i in range(c.n_layers):
            specs += [
                (f"l{i}.ln1", (c.d_model,)),
                (f"l{i}.wq", (c.d_model, c.qkv_dim)),
                (f"l{i}.wk", (c.d_model, c.qkv_dim)),
                (f"l{i}.wv", (c.d_model, c.qkv_dim)),
                (f"l{i}.wo", (c.qkv_dim, c.d_model)),
                (f"l{i}.ln2", (c.d_model,)),
                (f"l{i}.w1", (c.d_model, c.d_ff)),
                (f"l{i}.w2", (c.d_ff, c.d_model)),
            ]
        specs.append(("ln_f", (c.d_model,)))
        return specs

    def n_params(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.param_specs())


def init_params(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """Deterministic random init, returned as the flat f32 vector."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in cfg.param_specs():
        if name.endswith(("ln1", "ln2", "ln_f")):
            chunks.append(np.ones(shape, np.float32).ravel())
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.5 / np.sqrt(fan_in)
            chunks.append(
                (rng.standard_normal(np.prod(shape)) * std).astype(np.float32)
            )
    return np.concatenate(chunks)


def unflatten(cfg: ModelConfig, flat) -> dict[str, Any]:
    """Slice the flat vector back into named tensors (jit-traceable)."""
    params: dict[str, Any] = {}
    off = 0
    for name, shape in cfg.param_specs():
        n = int(np.prod(shape))
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    return params


def _rmsnorm(x, scale, eps: float = 1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _write_cache(cache, new, start):
    """Write ``new`` [B, C, H, D] into ``cache`` [B, T, H, D] at per-batch
    offsets ``start`` [B] (int32)."""

    def one(c, n, s):
        return jax.lax.dynamic_update_slice(c, n, (s, 0, 0))

    return jax.vmap(one)(cache, new, start)


def _layer_decode(cfg, params, i, x, k_cache, v_cache, cache_lens):
    """One transformer layer of a single-token decode step.

    x: [B, d]; k/v_cache: [B, T, H, D] (this layer's slice);
    cache_lens: [B] lengths INCLUDING the new token's slot.
    """
    B = x.shape[0]
    c = cfg
    h = _rmsnorm(x, params[f"l{i}.ln1"])
    q = (h @ params[f"l{i}.wq"]).reshape(B, c.n_heads, c.head_dim)
    k = (h @ params[f"l{i}.wk"]).reshape(B, 1, c.n_heads, c.head_dim)
    v = (h @ params[f"l{i}.wv"]).reshape(B, 1, c.n_heads, c.head_dim)
    # The new token occupies slot cache_lens-1.
    k_cache = _write_cache(k_cache, k, cache_lens - 1)
    v_cache = _write_cache(v_cache, v, cache_lens - 1)
    attn = attention.decode_attention(q, k_cache, v_cache, cache_lens)
    x = x + attn.reshape(B, c.qkv_dim) @ params[f"l{i}.wo"]
    h = _rmsnorm(x, params[f"l{i}.ln2"])
    x = x + jax.nn.gelu(h @ params[f"l{i}.w1"]) @ params[f"l{i}.w2"]
    return x, k_cache, v_cache


def _layer_extend(cfg, params, i, x, k_cache, v_cache, cache_lens):
    """One transformer layer of a C-token extend step.  x: [B, C, d]."""
    B, C, _ = x.shape
    c = cfg
    h = _rmsnorm(x, params[f"l{i}.ln1"])
    q = (h @ params[f"l{i}.wq"]).reshape(B, C, c.n_heads, c.head_dim)
    k = (h @ params[f"l{i}.wk"]).reshape(B, C, c.n_heads, c.head_dim)
    v = (h @ params[f"l{i}.wv"]).reshape(B, C, c.n_heads, c.head_dim)
    k_cache = _write_cache(k_cache, k, cache_lens)
    v_cache = _write_cache(v_cache, v, cache_lens)
    attn = attention.extend_attention(
        q, k_cache, v_cache, cache_lens, q_block=min(C, attention.Q_BLOCK)
    )
    x = x + attn.reshape(B, C, c.qkv_dim) @ params[f"l{i}.wo"]
    h = _rmsnorm(x, params[f"l{i}.ln2"])
    x = x + jax.nn.gelu(h @ params[f"l{i}.w1"]) @ params[f"l{i}.w2"]
    return x, k_cache, v_cache


def decode_step(cfg: ModelConfig, flat_params, tokens, k_cache, v_cache, cache_lens):
    """One greedy decode step for a fixed batch.

    Args:
      flat_params: [n_params] f32
      tokens:      [B] int32 — the token generated at the previous step
      k_cache:     [L, B, T, H, D] f32
      v_cache:     [L, B, T, H, D] f32
      cache_lens:  [B] int32 — valid cache length BEFORE this token

    Returns (logits [B, vocab], k_cache', v_cache', cache_lens+1).
    """
    c = cfg
    params = unflatten(c, flat_params)
    new_lens = cache_lens + 1
    pos = jnp.clip(cache_lens, 0, c.max_seq - 1)
    x = params["embed"][tokens] + params["pos_embed"][pos]  # [B, d]
    ks, vs = [], []
    for i in range(c.n_layers):
        x, kc, vc = _layer_decode(c, params, i, x, k_cache[i], v_cache[i], new_lens)
        ks.append(kc)
        vs.append(vc)
    x = _rmsnorm(x, params["ln_f"])
    logits = x @ params["embed"].T  # tied head
    return logits, jnp.stack(ks), jnp.stack(vs), new_lens


def extend_chunk(
    cfg: ModelConfig, flat_params, tokens, k_cache, v_cache, cache_lens, chunk_lens
):
    """Append a C-token chunk per sequence (prefill / cache-hit resume).

    Args:
      tokens:     [B, C] int32, right-padded per ``chunk_lens``
      cache_lens: [B] int32 cached-prefix length (radix-cache hit length)
      chunk_lens: [B] int32 valid tokens in this chunk (1..C)

    Returns (next_logits [B, vocab] — logits at each sequence's last valid
    chunk position, k_cache', v_cache', cache_lens+chunk_lens).

    Padded rows write garbage K/V beyond ``cache_lens+chunk_lens``; those
    slots are overwritten before they ever become visible because
    attention masks strictly by length.
    """
    c = cfg
    B, C = tokens.shape
    params = unflatten(c, flat_params)
    pos = jnp.clip(cache_lens[:, None] + jnp.arange(C)[None, :], 0, c.max_seq - 1)
    x = params["embed"][tokens] + params["pos_embed"][pos]  # [B, C, d]
    ks, vs = [], []
    for i in range(c.n_layers):
        x, kc, vc = _layer_extend(c, params, i, x, k_cache[i], v_cache[i], cache_lens)
        ks.append(kc)
        vs.append(vc)
    x = _rmsnorm(x, params["ln_f"])  # [B, C, d]
    last = jnp.take_along_axis(
        x, jnp.maximum(chunk_lens - 1, 0)[:, None, None], axis=1
    )[:, 0, :]
    logits = last @ params["embed"].T
    return logits, jnp.stack(ks), jnp.stack(vs), cache_lens + chunk_lens


def reference_forward(cfg: ModelConfig, flat_params, tokens):
    """Oracle: full non-cached forward over a [B, T] prompt, pure jnp
    attention (no Pallas, no cache).  Returns logits [B, T, vocab]."""
    from .kernels import ref

    c = cfg
    B, T = tokens.shape
    params = unflatten(c, flat_params)
    pos = jnp.arange(T)
    x = params["embed"][tokens] + params["pos_embed"][pos][None, :, :]
    lens = jnp.full((B,), T, jnp.int32)
    for i in range(c.n_layers):
        h = _rmsnorm(x, params[f"l{i}.ln1"])
        q = (h @ params[f"l{i}.wq"]).reshape(B, T, c.n_heads, c.head_dim)
        k = (h @ params[f"l{i}.wk"]).reshape(B, T, c.n_heads, c.head_dim)
        v = (h @ params[f"l{i}.wv"]).reshape(B, T, c.n_heads, c.head_dim)
        attn = ref.prefill_attention_ref(q, k, v, lens)
        x = x + attn.reshape(B, T, c.qkv_dim) @ params[f"l{i}.wo"]
        h = _rmsnorm(x, params[f"l{i}.ln2"])
        x = x + jax.nn.gelu(h @ params[f"l{i}.w1"]) @ params[f"l{i}.w2"]
    x = _rmsnorm(x, params["ln_f"])
    return x @ params["embed"].T
