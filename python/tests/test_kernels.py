"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core numeric signal for the whole stack — the rust runtime
executes HLO lowered from exactly these kernels, so allclose here plus the
HLO round-trip test in rust gives end-to-end numeric confidence.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref

RTOL = 2e-5
ATOL = 2e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize(
    "B,T,H,D,k_block",
    [
        (1, 128, 1, 64, 128),
        (2, 256, 2, 64, 128),
        (3, 256, 4, 32, 64),
        (8, 256, 2, 64, 128),
        (1, 512, 2, 128, 128),
    ],
)
def test_decode_matches_ref(B, T, H, D, k_block):
    rng = np.random.default_rng(42 + B + T)
    q = _rand(rng, B, H, D)
    k = _rand(rng, B, T, H, D)
    v = _rand(rng, B, T, H, D)
    lens = jnp.asarray(rng.integers(1, T + 1, B), jnp.int32)
    out = attention.decode_attention(q, k, v, lens, k_block=k_block)
    exp = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)


def test_decode_length_one_attends_only_first_slot():
    """With length 1, output must equal v[:, 0] exactly (softmax of 1)."""
    rng = np.random.default_rng(0)
    B, T, H, D = 2, 128, 2, 64
    q = _rand(rng, B, H, D)
    k = _rand(rng, B, T, H, D)
    v = _rand(rng, B, T, H, D)
    lens = jnp.ones((B,), jnp.int32)
    out = attention.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(out, v[:, 0], rtol=RTOL, atol=ATOL)


def test_decode_ignores_garbage_beyond_length():
    """Poisoning cache rows beyond the valid length must not change output."""
    rng = np.random.default_rng(1)
    B, T, H, D = 2, 256, 2, 64
    q = _rand(rng, B, H, D)
    k = _rand(rng, B, T, H, D)
    v = _rand(rng, B, T, H, D)
    lens = jnp.asarray([100, 37], jnp.int32)
    base = attention.decode_attention(q, k, v, lens)
    k2 = k.at[:, 150:].set(1e6)
    v2 = v.at[:, 150:].set(-1e6)
    poisoned = attention.decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(base, poisoned, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize(
    "B,T,H,D,qb,kb",
    [
        (1, 128, 1, 64, 128, 128),
        (2, 256, 2, 64, 128, 128),
        (2, 256, 2, 64, 64, 64),
        (4, 256, 1, 32, 128, 128),
    ],
)
def test_prefill_matches_ref(B, T, H, D, qb, kb):
    rng = np.random.default_rng(7 + B + T)
    q = _rand(rng, B, T, H, D)
    k = _rand(rng, B, T, H, D)
    v = _rand(rng, B, T, H, D)
    lens = jnp.asarray(rng.integers(1, T + 1, B), jnp.int32)
    out = attention.prefill_attention(q, k, v, lens, q_block=qb, k_block=kb)
    exp = ref.prefill_attention_ref(q, k, v, lens)
    for b in range(B):
        L = int(lens[b])
        np.testing.assert_allclose(out[b, :L], exp[b, :L], rtol=RTOL, atol=ATOL)


def test_prefill_row_zero_is_v_zero():
    """First prompt row attends only to itself."""
    rng = np.random.default_rng(2)
    B, T, H, D = 2, 128, 2, 64
    q, k, v = (_rand(rng, B, T, H, D) for _ in range(3))
    lens = jnp.full((B,), T, jnp.int32)
    out = attention.prefill_attention(q, k, v, lens)
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("clen", [0, 1, 100, 128])
def test_extend_matches_ref_against_concat(clen):
    """extend(q_chunk | cached prefix) == ref causal attention over the
    concatenated sequence, restricted to the chunk's rows."""
    rng = np.random.default_rng(3 + clen)
    B, T, H, D, C = 2, 256, 2, 64, 128
    # Build a full sequence, then split into cached prefix + chunk.
    total = clen + C
    q_full = _rand(rng, B, T, H, D)
    k_full = _rand(rng, B, T, H, D)
    v_full = _rand(rng, B, T, H, D)
    lens_full = jnp.full((B,), total, jnp.int32)
    exp = ref.prefill_attention_ref(q_full, k_full, v_full, lens_full)

    q_chunk = q_full[:, clen : clen + C]
    cache_lens = jnp.full((B,), clen, jnp.int32)
    out = attention.extend_attention(q_chunk, k_full, v_full, cache_lens)
    np.testing.assert_allclose(
        out, exp[:, clen : clen + C], rtol=RTOL, atol=ATOL
    )


def test_extend_c1_equals_decode():
    """extend with a single-token chunk must agree with the decode kernel."""
    rng = np.random.default_rng(4)
    B, T, H, D = 2, 256, 2, 64
    k = _rand(rng, B, T, H, D)
    v = _rand(rng, B, T, H, D)
    q = _rand(rng, B, 1, H, D)
    clens = jnp.asarray([10, 200], jnp.int32)
    out_e = attention.extend_attention(q, k, v, clens, q_block=1)
    out_d = attention.decode_attention(q[:, 0], k, v, clens + 1)
    np.testing.assert_allclose(out_e[:, 0], out_d, rtol=RTOL, atol=ATOL)


# --- hypothesis sweeps over shapes/lengths (interpret mode is slow: keep
# --- the example budget small but the strategy space wide).
@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 4),
    H=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([32, 64]),
    tblocks=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_decode_hypothesis(B, H, D, tblocks, seed, data):
    T = 128 * tblocks
    rng = np.random.default_rng(seed)
    q = _rand(rng, B, H, D)
    k = _rand(rng, B, T, H, D)
    v = _rand(rng, B, T, H, D)
    lens = jnp.asarray(
        [data.draw(st.integers(1, T), label=f"len{b}") for b in range(B)],
        jnp.int32,
    )
    out = attention.decode_attention(q, k, v, lens)
    exp = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    B=st.integers(1, 2),
    H=st.sampled_from([1, 2]),
    D=st.sampled_from([32, 64]),
    clen=st.integers(0, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_extend_hypothesis(B, H, D, clen, seed):
    T, C = 256, 128
    rng = np.random.default_rng(seed)
    q_full = _rand(rng, B, T, H, D)
    k_full = _rand(rng, B, T, H, D)
    v_full = _rand(rng, B, T, H, D)
    exp = ref.prefill_attention_ref(
        q_full, k_full, v_full, jnp.full((B,), clen + C, jnp.int32)
    )
    out = attention.extend_attention(
        q_full[:, clen : clen + C], k_full, v_full,
        jnp.full((B,), clen, jnp.int32),
    )
    np.testing.assert_allclose(out, exp[:, clen : clen + C], rtol=1e-4, atol=1e-4)
