"""L2 correctness: cached decode/extend graphs vs the uncached oracle.

``reference_forward`` runs the whole prompt with full causal attention and
no KV cache; the serving graphs must reproduce its logits through any
split of the sequence into (extend chunk)* (decode step)*.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model as model_lib

CFG = model_lib.ModelConfig(
    n_layers=2, d_model=64, n_heads=2, head_dim=32, d_ff=128, max_seq=256
)
PARAMS = jnp.asarray(model_lib.init_params(CFG, seed=0))
RTOL = 5e-4
ATOL = 5e-4


def _empty_cache(batch):
    shape = (CFG.n_layers, batch, CFG.max_seq, CFG.n_heads, CFG.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _tokens(rng, *shape):
    return jnp.asarray(rng.integers(0, CFG.vocab, shape), jnp.int32)


def test_param_layout_roundtrip():
    params = model_lib.unflatten(CFG, PARAMS)
    assert params["embed"].shape == (CFG.vocab, CFG.d_model)
    assert params["l0.w1"].shape == (CFG.d_model, CFG.d_ff)
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == CFG.n_params() == PARAMS.shape[0]


def test_extend_prefill_matches_reference():
    """One full-prompt extend == uncached reference forward (last logit)."""
    rng = np.random.default_rng(0)
    B, C = 2, 128
    toks = _tokens(rng, B, C)
    kc, vc = _empty_cache(B)
    chunk_lens = jnp.asarray([C, 70], jnp.int32)
    logits, kc, vc, lens = model_lib.extend_chunk(
        CFG, PARAMS, toks, kc, vc, jnp.zeros((B,), jnp.int32), chunk_lens
    )
    ref = model_lib.reference_forward(CFG, PARAMS, toks)
    for b in range(B):
        L = int(chunk_lens[b])
        np.testing.assert_allclose(
            logits[b], ref[b, L - 1], rtol=RTOL, atol=ATOL
        )
    np.testing.assert_array_equal(np.asarray(lens), np.asarray(chunk_lens))


def test_decode_steps_match_reference():
    """prefill(T-k) + k decode steps == reference over the full prompt."""
    rng = np.random.default_rng(1)
    B, T, k = 2, 128, 3
    toks = _tokens(rng, B, T)
    ref = model_lib.reference_forward(CFG, PARAMS, toks)

    kc, vc = _empty_cache(B)
    pre = T - k
    logits, kc, vc, lens = model_lib.extend_chunk(
        CFG, PARAMS, toks[:, :pre], kc, vc,
        jnp.zeros((B,), jnp.int32), jnp.full((B,), pre, jnp.int32),
    )
    np.testing.assert_allclose(logits, ref[:, pre - 1], rtol=RTOL, atol=ATOL)
    for j in range(k):
        logits, kc, vc, lens = model_lib.decode_step(
            CFG, PARAMS, toks[:, pre + j], kc, vc, lens
        )
        np.testing.assert_allclose(
            logits, ref[:, pre + j], rtol=RTOL, atol=ATOL
        )
    assert int(lens[0]) == T


def test_chunked_extend_matches_single_extend():
    """Two 128-chunk extends == reference at the final position, i.e. the
    radix-cache resume path (cache_lens > 0) is numerically transparent."""
    rng = np.random.default_rng(2)
    B, C = 1, 128
    toks = _tokens(rng, B, 2 * C)
    ref = model_lib.reference_forward(CFG, PARAMS, toks)

    kc, vc = _empty_cache(B)
    full = jnp.full((B,), C, jnp.int32)
    _, kc, vc, lens = model_lib.extend_chunk(
        CFG, PARAMS, toks[:, :C], kc, vc, jnp.zeros((B,), jnp.int32), full
    )
    logits, kc, vc, lens = model_lib.extend_chunk(
        CFG, PARAMS, toks[:, C:], kc, vc, lens, full
    )
    np.testing.assert_allclose(logits, ref[:, -1], rtol=RTOL, atol=ATOL)


def test_batch_elements_are_independent():
    """Changing sequence 1 must not perturb sequence 0's logits (no
    cross-batch leakage through the kernels or cache writes)."""
    rng = np.random.default_rng(3)
    B, C = 2, 128
    toks = _tokens(rng, B, C)
    kc, vc = _empty_cache(B)
    zeros = jnp.zeros((B,), jnp.int32)
    full = jnp.full((B,), C, jnp.int32)
    logits_a, *_ = model_lib.extend_chunk(CFG, PARAMS, toks, kc, vc, zeros, full)
    toks_b = toks.at[1].set(_tokens(rng, C))
    logits_b, *_ = model_lib.extend_chunk(CFG, PARAMS, toks_b, kc, vc, zeros, full)
    np.testing.assert_allclose(logits_a[0], logits_b[0], rtol=RTOL, atol=ATOL)
    assert not np.allclose(logits_a[1], logits_b[1], rtol=RTOL, atol=ATOL)


def test_padded_chunk_rows_do_not_corrupt_later_steps():
    """Extend with chunk_lens < C, then continue decoding: the garbage K/V
    written by padded rows beyond the valid length must be invisible."""
    rng = np.random.default_rng(4)
    B, C = 1, 128
    L = 50
    toks = _tokens(rng, B, C)
    ref = model_lib.reference_forward(CFG, PARAMS, toks[:, : L + 1])

    kc, vc = _empty_cache(B)
    logits, kc, vc, lens = model_lib.extend_chunk(
        CFG, PARAMS, toks, kc, vc,
        jnp.zeros((B,), jnp.int32), jnp.asarray([L], jnp.int32),
    )
    np.testing.assert_allclose(logits, ref[:, L - 1], rtol=RTOL, atol=ATOL)
    # Decode the next real token; its logits must match the oracle.
    logits, kc, vc, lens = model_lib.decode_step(
        CFG, PARAMS, toks[:, L], kc, vc, lens
    )
    np.testing.assert_allclose(logits, ref[:, L], rtol=RTOL, atol=ATOL)


def test_n_params_default_config():
    cfg = model_lib.ModelConfig()
    # embed + pos + layers + ln_f, all f32: sanity-pin the artifact size.
    assert cfg.n_params() == sum(
        int(np.prod(s)) for _, s in cfg.param_specs()
    )
    assert cfg.n_params() < 2_000_000  # params.bin stays under 8 MB
